//! The original worklist-of-rounds simulation engine, kept verbatim as
//! the semantic reference for [`super::engine`] (DESIGN.md §7).
//!
//! Compiled only for tests and under the `sim-naive` feature: the parity
//! property test (`sim::parity_tests`) asserts that the event-driven
//! engine reproduces this engine's makespan and per-kernel utilization on
//! randomized specs, and `benches/sim_engine.rs --features sim-naive`
//! measures the host-wallclock gap between the two.
//!
//! Characteristics being replaced: every round rescans all nodes,
//! `token_at` costs two integer divisions per edge per iteration, and
//! `produced`/`consumed` record every token timestamp (O(windows) memory
//! per edge).

use super::{report, Prep};
use crate::arch::ArchConfig;
use crate::graph::place::Placement;
use crate::graph::route::Routing;
use crate::graph::Graph;
use crate::sim::{SimReport, EDGE_CAPACITY};
use crate::{Error, Result};

/// Simulate a placed+routed graph with the reference engine.
///
/// Shares [`super::prepare`] with the event engine so both derive node
/// schedules, edge latencies and adjacency identically; the component
/// partition and steady-state periods that `Prep` also carries are
/// engine-side acceleration metadata the reference loop deliberately
/// ignores — it remains the plain semantic baseline the parity suite
/// compares against.
pub fn simulate(
    graph: &Graph,
    placement: &Placement,
    routing: &Routing,
    arch: &ArchConfig,
) -> Result<SimReport> {
    let prep = super::prepare(graph, routing, arch);
    let (makespan, busy_total) = run(graph, &prep)?;
    Ok(report::build(graph, placement, routing, arch, makespan, &busy_total, &prep.sched))
}

/// The original token-dataflow event loop: worklist rounds over all nodes.
pub(crate) fn run(graph: &Graph, prep: &Prep) -> Result<(f64, Vec<f64>)> {
    let n = graph.nodes.len();
    let sched = &prep.sched;
    let in_adj = &prep.in_adj;
    let out_adj = &prep.out_adj;
    let edge_windows = &prep.edge_windows;

    // produced[e][j] = time token j becomes available at the consumer;
    // consumed[e][j] = time the consumer finished with token j (frees space).
    let mut produced: Vec<Vec<f64>> =
        edge_windows.iter().map(|&w| Vec::with_capacity(w)).collect();
    let mut consumed: Vec<Vec<f64>> =
        edge_windows.iter().map(|&w| Vec::with_capacity(w)).collect();
    let mut done_iters = vec![0usize; n];
    let mut busy_until = vec![0.0f64; n];
    let mut busy_total = vec![0.0f64; n];

    // iteration→token maps (rate matching).
    let token_at = |windows: usize, iters: usize, k: usize| -> Option<usize> {
        // consume/produce token t at iteration k iff t = floor((k+1)*W/I) - 1
        // advanced past floor(k*W/I) - 1; evenly spreads W tokens over I.
        let before = k * windows / iters;
        let after = (k + 1) * windows / iters;
        (after > before).then(|| after - 1)
    };

    let total_iters: usize = sched.iter().map(|s| s.iters).sum();
    let mut completed = 0usize;
    // Worklist rounds: each pass tries to advance every node by as many
    // iterations as its dependencies allow. The (node, iteration)
    // dependency graph is acyclic, so progress is guaranteed.
    let mut progressed = true;
    while completed < total_iters {
        if !progressed {
            return Err(Error::Sim(format!(
                "deadlock: {completed}/{total_iters} iterations completed"
            )));
        }
        progressed = false;
        for id in 0..n {
            loop {
                let k = done_iters[id];
                if k >= sched[id].iters {
                    break;
                }
                // dependencies: input tokens present, output space known.
                let mut start: f64 = if k == 0 {
                    sched[id].launch_s
                } else {
                    busy_until[id]
                };
                let mut ready = true;
                for &eid in &in_adj[id] {
                    if let Some(t) = token_at(edge_windows[eid], sched[id].iters, k) {
                        match produced[eid].get(t) {
                            Some(&avail) => start = start.max(avail),
                            None => {
                                ready = false;
                                break;
                            }
                        }
                    }
                }
                if ready {
                    for &eid in &out_adj[id] {
                        if let Some(t) = token_at(edge_windows[eid], sched[id].iters, k) {
                            if t >= EDGE_CAPACITY {
                                // space frees when the consumer finishes
                                // token t - capacity.
                                match consumed[eid].get(t - EDGE_CAPACITY) {
                                    Some(&freed) => start = start.max(freed),
                                    None => {
                                        ready = false;
                                        break;
                                    }
                                }
                            }
                        }
                    }
                }
                if !ready {
                    break;
                }
                let finish = start + sched[id].service_s;
                busy_until[id] = finish;
                busy_total[id] += sched[id].service_s;
                for &eid in &in_adj[id] {
                    if let Some(t) = token_at(edge_windows[eid], sched[id].iters, k) {
                        debug_assert_eq!(consumed[eid].len(), t);
                        consumed[eid].push(finish);
                    }
                }
                for &eid in &out_adj[id] {
                    if let Some(t) = token_at(edge_windows[eid], sched[id].iters, k) {
                        debug_assert_eq!(produced[eid].len(), t);
                        produced[eid].push(finish + prep.edge_latency[eid]);
                    }
                }
                done_iters[id] += 1;
                completed += 1;
                progressed = true;
            }
        }
    }

    // --- conservation checks --------------------------------------------------
    for e in &graph.edges {
        if produced[e.id].len() != e.num_windows() || consumed[e.id].len() != e.num_windows() {
            return Err(Error::Sim(format!(
                "edge {}: {} produced / {} consumed of {} windows",
                e.id,
                produced[e.id].len(),
                consumed[e.id].len(),
                e.num_windows()
            )));
        }
    }

    let makespan = busy_until.iter().cloned().fold(0.0, f64::max);
    Ok((makespan, busy_total))
}
