//! Engine-parity suite (DESIGN.md §7): the event-driven [`super::engine`]
//! must reproduce the reference [`super::naive`] engine — makespan within
//! 1e-9 relative, identical per-kernel iteration counts, utilization
//! within 1e-9 relative — across randomized specs spanning Pl/OnChip
//! sources, splits, bursts and composed pipelines; across fast-forward
//! generations (PR 2 uniform-only vs multi-rate); and across component
//! thread counts, where reports must additionally be **bit-identical**
//! (parallelism may only change which host thread runs which component).
//! Deterministic cases are chosen so both the uniform and the multi-rate
//! steady-state fast-forward provably engage.

use super::{engine, naive, prepare, prepare_opts, SimOptions, SimReport};
use crate::blas::RoutineKind;
use crate::graph::place::{Location, Placement};
use crate::graph::route::route;
use crate::graph::{EdgeKind, Graph, NodeKind};
use crate::pipeline::lower_spec;
use crate::spec::{Connection, DataSource, RoutineSpec, Spec};
use crate::util::proptest::{forall, Config as PropConfig, Gen, Prop};
use crate::util::rng::Rng;
use crate::Error;

fn rel_close(a: f64, b: f64, rtol: f64) -> bool {
    (a - b).abs() <= rtol * a.abs().max(b.abs()) + 1e-300
}

/// Random spec generator: 1–4 routines over both data sources, optional
/// split/burst/window/alpha, with compatible neighbours sometimes chained
/// into an on-chip pipeline. Deliberately narrower sizes than
/// `tests/properties.rs`'s generator (every case here runs *several*
/// engine configurations) but wider non-functional coverage (splits).
fn spec_gen() -> Gen<Spec> {
    Gen::new(|rng: &mut Rng| {
        let kinds = [
            RoutineKind::Axpy,
            RoutineKind::Scal,
            RoutineKind::Copy,
            RoutineKind::Dot,
            RoutineKind::Asum,
            RoutineKind::Gemv,
            RoutineKind::Axpydot,
        ];
        let splittable = [
            RoutineKind::Axpy,
            RoutineKind::Scal,
            RoutineKind::Copy,
            RoutineKind::Dot,
            RoutineKind::Asum,
        ];
        let n_routines = rng.range(1, 4);
        let source = if rng.bool() { DataSource::Pl } else { DataSource::OnChip };
        let mut spec =
            Spec { platform: "vck5000".into(), data_source: source, ..Default::default() };
        for i in 0..n_routines {
            let kind = *rng.choose(&kinds);
            let size = if kind.level() >= 2 {
                1 << rng.range(5, 8) // 32..256
            } else {
                1 << rng.range(8, 13) // 256..8192: enough iterations to
                                      // reach steady state at small windows
            };
            let mut r = RoutineSpec::new(kind, format!("k{i}"), size);
            if kind.level() == 1 && rng.bool() {
                r.window = Some(1 << rng.range(4, 8)); // 16..256
            }
            if splittable.contains(&kind) && rng.range(0, 3) == 0 {
                r.split = 1 << rng.range(1, 2); // 2 or 4 (divides the pow-2 size)
            }
            r.burst = rng.bool();
            if rng.bool() {
                r.alpha = Some(rng.f32_in(-4.0, 4.0));
            }
            spec.routines.push(r);
        }
        // maybe chain compatible vector outputs into vector inputs
        for i in 0..spec.routines.len().saturating_sub(1) {
            let (a, b) = (spec.routines[i].clone(), spec.routines[i + 1].clone());
            if a.kind.is_composite() || b.kind.is_composite() || a.split > 1 || b.split > 1 {
                continue;
            }
            let out_vec = a.kind.outputs().iter().find(|p| p.ty == crate::blas::PortType::Vector);
            let in_vec = b.kind.inputs().iter().find(|p| p.ty == crate::blas::PortType::Vector);
            if let (Some(o), Some(inp)) = (out_vec, in_vec) {
                if a.size == b.size && rng.bool() {
                    spec.connections.push(Connection {
                        from_kernel: a.name.clone(),
                        from_port: o.name.to_string(),
                        to_kernel: b.name.clone(),
                        to_port: inp.name.to_string(),
                    });
                }
            }
        }
        spec
    })
}

/// Loosely compare two reports (different engines / fast-forward
/// generations: equal up to floating-point accumulation order).
fn assert_reports_close(label: &str, a: &SimReport, b: &SimReport) -> Result<(), String> {
    if !rel_close(a.makespan_s, b.makespan_s, 1e-9) {
        return Err(format!("{label}: makespan diverged: {} vs {}", a.makespan_s, b.makespan_s));
    }
    if a.kernels.len() != b.kernels.len() {
        return Err(format!("{label}: kernel count diverged"));
    }
    for (x, y) in a.kernels.iter().zip(&b.kernels) {
        if x.iterations != y.iterations {
            return Err(format!(
                "{label}/{}: iterations {} vs {}",
                x.name, x.iterations, y.iterations
            ));
        }
        if !rel_close(x.utilization, y.utilization, 1e-9) {
            return Err(format!(
                "{label}/{}: utilization {} vs {}",
                x.name, x.utilization, y.utilization
            ));
        }
    }
    Ok(())
}

/// Strictly compare two reports (same engine, different thread counts:
/// every float must be bit-identical — parallelism is pure scheduling).
fn assert_reports_bit_identical(label: &str, a: &SimReport, b: &SimReport) -> Result<(), String> {
    if a.makespan_s.to_bits() != b.makespan_s.to_bits() {
        return Err(format!(
            "{label}: makespan bits diverged: {} vs {}",
            a.makespan_s, b.makespan_s
        ));
    }
    if a.kernels.len() != b.kernels.len() {
        return Err(format!("{label}: kernel count diverged"));
    }
    for (x, y) in a.kernels.iter().zip(&b.kernels) {
        if x.iterations != y.iterations
            || x.busy_s.to_bits() != y.busy_s.to_bits()
            || x.utilization.to_bits() != y.utilization.to_bits()
        {
            return Err(format!("{label}/{}: per-kernel stats diverged bitwise", x.name));
        }
    }
    Ok(())
}

/// Compare naive vs the event engine across fast-forward generations and
/// thread counts on one spec; `Err` describes the divergence.
fn check_parity(spec: &Spec) -> Result<(), String> {
    let plan = lower_spec(spec).map_err(|e| format!("lower: {e}"))?;
    let (g, p, r, a) = (plan.graph(), plan.placement(), plan.routing(), plan.arch());
    let sim = |opts: &SimOptions| {
        super::simulate_with(g, p, r, a, opts).map_err(|e| format!("engine: {e}"))
    };
    let multirate_t1 = sim(&SimOptions { multirate: true, threads: 1 })?;
    let multirate_t4 = sim(&SimOptions { multirate: true, threads: 4 })?;
    let uniform_t1 = sim(&SimOptions { multirate: false, threads: 1 })?;
    let slow = naive::simulate(g, p, r, a).map_err(|e| format!("naive: {e}"))?;
    assert_reports_close("multirate-vs-naive", &multirate_t1, &slow)?;
    assert_reports_close("uniform-vs-naive", &uniform_t1, &slow)?;
    assert_reports_bit_identical("threads-1-vs-4", &multirate_t1, &multirate_t4)?;
    Ok(())
}

#[test]
fn randomized_specs_agree_across_engines_and_thread_counts() {
    forall(&spec_gen(), PropConfig { cases: 60, ..Default::default() }, |spec| {
        if crate::spec::validate(spec).is_err() {
            return Prop::Discard;
        }
        match check_parity(spec) {
            Ok(()) => Prop::Pass,
            Err(e) => Prop::Fail(e),
        }
    });
}

/// Run the event engine directly and return its fast-forward stats.
fn run_with_stats(spec: &Spec, multirate: bool) -> (f64, engine::EngineStats) {
    let plan = lower_spec(spec).unwrap();
    let prep = prepare_opts(plan.graph(), plan.routing(), plan.arch(), multirate);
    let (makespan, _busy, stats) =
        engine::run(plan.graph(), plan.placement(), &prep, None, 1).unwrap();
    (makespan, stats)
}

#[test]
fn fast_forward_engages_and_matches_on_large_axpy() {
    let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::Pl);
    let (_, stats) = run_with_stats(&spec, true);
    assert!(stats.ff_jumps > 0, "fast-forward never engaged on the flagship case");
    assert!(stats.ff_iters > 0);
    check_parity(&spec).unwrap();
}

#[test]
fn fast_forward_matches_on_onchip_axpy() {
    let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::OnChip);
    let (_, stats) = run_with_stats(&spec, true);
    assert!(stats.ff_iters > 0);
    check_parity(&spec).unwrap();
}

#[test]
fn fast_forward_matches_on_deep_chain() {
    let spec = Spec::chain(RoutineKind::Copy, 8, 1 << 18);
    crate::spec::validate(&spec).unwrap();
    let (_, stats) = run_with_stats(&spec, true);
    assert!(stats.ff_iters > 0, "fast-forward never engaged on the 8-stage chain");
    check_parity(&spec).unwrap();
}

#[test]
fn fast_forward_matches_on_composed_axpydot() {
    check_parity(&Spec::axpydot_dataflow(1 << 18, 2.0)).unwrap();
}

/// The PR 5 headline property: gemv's re-read `x` edge makes the kernel's
/// dependency pattern repeat only every `n/16` iterations. The uniform
/// (PR 2) detector can at best skip fragments *between* `x` fires; the
/// multi-rate detector must engage across whole hyperperiods — and stay
/// parity-exact while doing so, in both generations.
#[test]
fn multirate_fast_forward_engages_on_gemv() {
    for n in [512usize, 1024] {
        let spec = Spec::single(RoutineKind::Gemv, "g", n, DataSource::Pl);
        let (_, multirate) = run_with_stats(&spec, true);
        assert!(
            multirate.ff_jumps > 0 && multirate.ff_iters > 0,
            "n={n}: multi-rate fast-forward never engaged on gemv ({multirate:?})"
        );
        check_parity(&spec).unwrap();
    }
}

#[test]
fn multirate_fast_forward_matches_on_onchip_gemv() {
    let spec = Spec::single(RoutineKind::Gemv, "g", 512, DataSource::OnChip);
    let (_, stats) = run_with_stats(&spec, true);
    assert!(stats.ff_iters > 0, "multi-rate fast-forward never engaged on on-chip gemv");
    check_parity(&spec).unwrap();
}

/// Property over the multi-rate flagship shapes: fast-forward must engage
/// (`ff_iters > 0`) AND makespan/utilization must match the reference
/// engine — a silently disengaged or silently wrong jump both fail.
#[test]
fn multirate_cases_engage_and_hold_parity() {
    let cases: Vec<(&str, Spec)> = vec![
        ("gemv/pl", Spec::single(RoutineKind::Gemv, "g", 1024, DataSource::Pl)),
        ("gemv/onchip", Spec::single(RoutineKind::Gemv, "g", 1024, DataSource::OnChip)),
        ("axpydot/composed", Spec::axpydot_dataflow(1 << 18, 2.0)),
        ("axpydot/composite", Spec::single(RoutineKind::Axpydot, "ad", 1 << 18, DataSource::Pl)),
    ];
    for (label, spec) in cases {
        crate::spec::validate(&spec).unwrap();
        let (_, stats) = run_with_stats(&spec, true);
        assert!(stats.ff_iters > 0, "{label}: fast-forward never engaged");
        check_parity(&spec).unwrap_or_else(|e| panic!("{label}: {e}"));
    }
}

/// Parallel component simulation is pure scheduling: a wide multi-routine
/// plan must produce bit-identical reports at every thread count, and the
/// traced variant must record the identical span set.
#[test]
fn parallel_components_are_bit_deterministic() {
    // 8 independent routines, sized so the engine's parallel fan-out gate
    // (PARALLEL_MIN_ITERS) is comfortably exceeded and component
    // parallelism genuinely engages.
    let mut spec = Spec { platform: "vck5000".into(), ..Default::default() };
    for i in 0..8 {
        spec.routines.push(RoutineSpec::new(RoutineKind::Axpy, format!("k{i}"), 1 << 19));
    }
    let plan = lower_spec(&spec).unwrap();
    let (g, p, r, a) = (plan.graph(), plan.placement(), plan.routing(), plan.arch());
    let serial =
        super::simulate_with(g, p, r, a, &SimOptions { multirate: true, threads: 1 }).unwrap();
    for threads in [2usize, 4, 8, 16] {
        let par =
            super::simulate_with(g, p, r, a, &SimOptions { multirate: true, threads }).unwrap();
        assert_reports_bit_identical(&format!("threads={threads}"), &serial, &par).unwrap();
    }
    // traced runs fan out too; span sets must be identical (order is
    // normalized by the engine's deterministic merge).
    let prep = prepare(g, r, a);
    let mut t1 = super::trace::Trace::default();
    let (_, _, stats) = engine::run(g, p, &prep, Some(&mut t1), 1).unwrap();
    assert_eq!(stats.components, 8, "one component per independent routine");
    let mut t8 = super::trace::Trace::default();
    engine::run(g, p, &prep, Some(&mut t8), 8).unwrap();
    assert_eq!(t1.spans.len(), t8.spans.len());
    for (x, y) in t1.spans.iter().zip(&t8.spans) {
        assert_eq!(x.node, y.node);
        assert_eq!(x.iteration, y.iteration);
        assert_eq!(x.start_s.to_bits(), y.start_s.to_bits());
        assert_eq!(x.end_s.to_bits(), y.end_s.to_bits());
    }
}

#[test]
fn wide_independent_components_agree() {
    let mut spec = Spec { platform: "vck5000".into(), ..Default::default() };
    for i in 0..8 {
        spec.routines.push(RoutineSpec::new(RoutineKind::Axpy, format!("k{i}"), 1 << 16));
    }
    check_parity(&spec).unwrap();
}

/// A graph with a dependency cycle can never progress: both engines must
/// return `Error::Sim("deadlock: …")` instead of looping forever. (Specs
/// cannot express this — `validate` rejects cycles — so the graph is
/// built by hand, as a corrupted-input regression.)
fn cyclic_fixture() -> (Graph, Placement, crate::graph::route::Routing, crate::arch::ArchConfig) {
    let kernel = |g: &mut Graph, name: &str| {
        g.add_node(
            name,
            NodeKind::AieKernel {
                kind: RoutineKind::Copy,
                size: 64,
                window: 16,
                vector_bits: 512,
                hint: None,
            },
        )
    };
    let mut g = Graph::default();
    let a = kernel(&mut g, "a");
    let b = kernel(&mut g, "b");
    g.add_edge(a, "z", b, "x", crate::blas::PortType::Vector, EdgeKind::Window, 64, 16);
    g.add_edge(b, "z", a, "x", crate::blas::PortType::Vector, EdgeKind::Window, 64, 16);
    let placement = Placement {
        locations: vec![Location::Tile { col: 0, row: 0 }, Location::Tile { col: 1, row: 0 }],
    };
    let arch = crate::arch::ArchConfig::vck5000();
    let routing = route(&g, &placement, &arch).unwrap();
    (g, placement, routing, arch)
}

#[test]
fn deadlocked_graph_errors_in_event_engine() {
    let (g, p, r, arch) = cyclic_fixture();
    match super::simulate(&g, &p, &r, &arch) {
        Err(Error::Sim(msg)) => assert!(msg.contains("deadlock"), "{msg}"),
        other => panic!("expected Sim(deadlock), got {other:?}"),
    }
}

#[test]
fn deadlocked_graph_errors_in_naive_engine() {
    let (g, p, r, arch) = cyclic_fixture();
    match naive::simulate(&g, &p, &r, &arch) {
        Err(Error::Sim(msg)) => assert!(msg.contains("deadlock"), "{msg}"),
        other => panic!("expected Sim(deadlock), got {other:?}"),
    }
}
