//! Engine-parity suite (DESIGN.md §7): the event-driven [`super::engine`]
//! must reproduce the reference [`super::naive`] engine — makespan within
//! 1e-9 relative, identical per-kernel iteration counts, utilization
//! within 1e-9 relative — across randomized specs spanning Pl/OnChip
//! sources, splits, bursts and composed pipelines, plus deterministic
//! cases chosen so the steady-state fast-forward provably engages.

use super::{engine, naive, prepare};
use crate::blas::RoutineKind;
use crate::graph::place::{Location, Placement};
use crate::graph::route::route;
use crate::graph::{EdgeKind, Graph, NodeKind};
use crate::pipeline::lower_spec;
use crate::spec::{Connection, DataSource, RoutineSpec, Spec};
use crate::util::proptest::{forall, Config as PropConfig, Gen, Prop};
use crate::util::rng::Rng;
use crate::Error;

fn rel_close(a: f64, b: f64, rtol: f64) -> bool {
    (a - b).abs() <= rtol * a.abs().max(b.abs()) + 1e-300
}

/// Random spec generator: 1–4 routines over both data sources, optional
/// split/burst/window/alpha, with compatible neighbours sometimes chained
/// into an on-chip pipeline. Deliberately narrower sizes than
/// `tests/properties.rs`'s generator (every case here runs *two* engines)
/// but wider non-functional coverage (splits).
fn spec_gen() -> Gen<Spec> {
    Gen::new(|rng: &mut Rng| {
        let kinds = [
            RoutineKind::Axpy,
            RoutineKind::Scal,
            RoutineKind::Copy,
            RoutineKind::Dot,
            RoutineKind::Asum,
            RoutineKind::Gemv,
            RoutineKind::Axpydot,
        ];
        let splittable = [
            RoutineKind::Axpy,
            RoutineKind::Scal,
            RoutineKind::Copy,
            RoutineKind::Dot,
            RoutineKind::Asum,
        ];
        let n_routines = rng.range(1, 4);
        let source = if rng.bool() { DataSource::Pl } else { DataSource::OnChip };
        let mut spec =
            Spec { platform: "vck5000".into(), data_source: source, ..Default::default() };
        for i in 0..n_routines {
            let kind = *rng.choose(&kinds);
            let size = if kind.level() >= 2 {
                1 << rng.range(5, 8) // 32..256
            } else {
                1 << rng.range(8, 13) // 256..8192: enough iterations to
                                      // reach steady state at small windows
            };
            let mut r = RoutineSpec::new(kind, format!("k{i}"), size);
            if kind.level() == 1 && rng.bool() {
                r.window = Some(1 << rng.range(4, 8)); // 16..256
            }
            if splittable.contains(&kind) && rng.range(0, 3) == 0 {
                r.split = 1 << rng.range(1, 2); // 2 or 4 (divides the pow-2 size)
            }
            r.burst = rng.bool();
            if rng.bool() {
                r.alpha = Some(rng.f32_in(-4.0, 4.0));
            }
            spec.routines.push(r);
        }
        // maybe chain compatible vector outputs into vector inputs
        for i in 0..spec.routines.len().saturating_sub(1) {
            let (a, b) = (spec.routines[i].clone(), spec.routines[i + 1].clone());
            if a.kind.is_composite() || b.kind.is_composite() || a.split > 1 || b.split > 1 {
                continue;
            }
            let out_vec = a.kind.outputs().iter().find(|p| p.ty == crate::blas::PortType::Vector);
            let in_vec = b.kind.inputs().iter().find(|p| p.ty == crate::blas::PortType::Vector);
            if let (Some(o), Some(inp)) = (out_vec, in_vec) {
                if a.size == b.size && rng.bool() {
                    spec.connections.push(Connection {
                        from_kernel: a.name.clone(),
                        from_port: o.name.to_string(),
                        to_kernel: b.name.clone(),
                        to_port: inp.name.to_string(),
                    });
                }
            }
        }
        spec
    })
}

/// Compare the two engines on one spec; `Err` describes the divergence.
fn check_parity(spec: &Spec) -> Result<(), String> {
    let plan = lower_spec(spec).map_err(|e| format!("lower: {e}"))?;
    let fast = super::simulate(plan.graph(), plan.placement(), plan.routing(), plan.arch())
        .map_err(|e| format!("engine: {e}"))?;
    let slow = naive::simulate(plan.graph(), plan.placement(), plan.routing(), plan.arch())
        .map_err(|e| format!("naive: {e}"))?;
    if !rel_close(fast.makespan_s, slow.makespan_s, 1e-9) {
        return Err(format!(
            "makespan diverged: engine {} vs naive {}",
            fast.makespan_s, slow.makespan_s
        ));
    }
    if fast.kernels.len() != slow.kernels.len() {
        return Err("kernel count diverged".into());
    }
    for (f, s) in fast.kernels.iter().zip(&slow.kernels) {
        if f.iterations != s.iterations {
            return Err(format!("{}: iterations {} vs {}", f.name, f.iterations, s.iterations));
        }
        if !rel_close(f.utilization, s.utilization, 1e-9) {
            return Err(format!(
                "{}: utilization {} vs {}",
                f.name, f.utilization, s.utilization
            ));
        }
    }
    Ok(())
}

#[test]
fn randomized_specs_agree_across_engines() {
    forall(&spec_gen(), PropConfig { cases: 60, ..Default::default() }, |spec| {
        if crate::spec::validate(spec).is_err() {
            return Prop::Discard;
        }
        match check_parity(spec) {
            Ok(()) => Prop::Pass,
            Err(e) => Prop::Fail(e),
        }
    });
}

/// Run the event engine directly and return its fast-forward stats.
fn run_with_stats(spec: &Spec) -> (f64, engine::EngineStats) {
    let plan = lower_spec(spec).unwrap();
    let prep = prepare(plan.graph(), plan.routing(), plan.arch());
    let (makespan, _busy, stats) =
        engine::run(plan.graph(), plan.placement(), &prep, None).unwrap();
    (makespan, stats)
}

#[test]
fn fast_forward_engages_and_matches_on_large_axpy() {
    let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::Pl);
    let (_, stats) = run_with_stats(&spec);
    assert!(stats.ff_jumps > 0, "fast-forward never engaged on the flagship case");
    assert!(stats.ff_iters > 0);
    check_parity(&spec).unwrap();
}

#[test]
fn fast_forward_matches_on_onchip_axpy() {
    let spec = Spec::single(RoutineKind::Axpy, "a", 1 << 20, DataSource::OnChip);
    let (_, stats) = run_with_stats(&spec);
    assert!(stats.ff_iters > 0);
    check_parity(&spec).unwrap();
}

#[test]
fn fast_forward_matches_on_deep_chain() {
    let spec = Spec::chain(RoutineKind::Copy, 8, 1 << 18);
    crate::spec::validate(&spec).unwrap();
    let (_, stats) = run_with_stats(&spec);
    assert!(stats.ff_iters > 0, "fast-forward never engaged on the 8-stage chain");
    check_parity(&spec).unwrap();
}

#[test]
fn fast_forward_matches_on_composed_axpydot() {
    check_parity(&Spec::axpydot_dataflow(1 << 18, 2.0)).unwrap();
}

#[test]
fn wide_independent_components_agree() {
    let mut spec = Spec { platform: "vck5000".into(), ..Default::default() };
    for i in 0..8 {
        spec.routines.push(RoutineSpec::new(RoutineKind::Axpy, format!("k{i}"), 1 << 16));
    }
    check_parity(&spec).unwrap();
}

/// A graph with a dependency cycle can never progress: both engines must
/// return `Error::Sim("deadlock: …")` instead of looping forever. (Specs
/// cannot express this — `validate` rejects cycles — so the graph is
/// built by hand, as a corrupted-input regression.)
fn cyclic_fixture() -> (Graph, Placement, crate::graph::route::Routing, crate::arch::ArchConfig) {
    let kernel = |g: &mut Graph, name: &str| {
        g.add_node(
            name,
            NodeKind::AieKernel {
                kind: RoutineKind::Copy,
                size: 64,
                window: 16,
                vector_bits: 512,
                hint: None,
            },
        )
    };
    let mut g = Graph::default();
    let a = kernel(&mut g, "a");
    let b = kernel(&mut g, "b");
    g.add_edge(a, "z", b, "x", crate::blas::PortType::Vector, EdgeKind::Window, 64, 16);
    g.add_edge(b, "z", a, "x", crate::blas::PortType::Vector, EdgeKind::Window, 64, 16);
    let placement = Placement {
        locations: vec![Location::Tile { col: 0, row: 0 }, Location::Tile { col: 1, row: 0 }],
    };
    let arch = crate::arch::ArchConfig::vck5000();
    let routing = route(&g, &placement, &arch).unwrap();
    (g, placement, routing, arch)
}

#[test]
fn deadlocked_graph_errors_in_event_engine() {
    let (g, p, r, arch) = cyclic_fixture();
    match super::simulate(&g, &p, &r, &arch) {
        Err(Error::Sim(msg)) => assert!(msg.contains("deadlock"), "{msg}"),
        other => panic!("expected Sim(deadlock), got {other:?}"),
    }
}

#[test]
fn deadlocked_graph_errors_in_naive_engine() {
    let (g, p, r, arch) = cyclic_fixture();
    match naive::simulate(&g, &p, &r, &arch) {
        Err(Error::Sim(msg)) => assert!(msg.contains("deadlock"), "{msg}"),
        other => panic!("expected Sim(deadlock), got {other:?}"),
    }
}
