//! Event-driven simulation engine (DESIGN.md §7).
//!
//! Replaces the worklist-of-rounds reference engine ([`super::naive`])
//! with three structural changes, none of which alter the token-dataflow
//! semantics:
//!
//! 1. **Ready-queue scheduling** — a node is (re)enqueued only when one of
//!    its neighbours completes an iteration (new token, or freed buffer
//!    space); popping a node drains every iteration its dependencies
//!    allow. No full-graph rescans: scheduling work is O(degree) per
//!    completed iteration, amortized O(1) for the bounded-degree graphs
//!    the builder emits.
//! 2. **Ring-buffer edge state** — the producer can run at most
//!    [`EDGE_CAPACITY`] tokens ahead of the consumer (ping-pong
//!    back-pressure), so only the last `EDGE_CAPACITY` produced/consumed
//!    timestamps are ever read. [`EdgeState`] keeps exactly those, in
//!    fixed-size arrays: O(1) memory per edge instead of O(windows).
//! 3. **Incremental stride counters** — rate matching (`W` tokens spread
//!    evenly over `I` iterations) fires token `t` at iteration `k` iff
//!    `⌊(k+1)W/I⌋ > ⌊kW/I⌋`. Since `W ≤ I` for every adjacent edge, the
//!    quotient advances by at most one per step, so an accumulator with
//!    `acc += W; if acc >= I { acc -= I; fire }` replaces both divisions
//!    of the old `token_at`.
//!
//! On top of the event loop sit two scaling mechanisms (both PR-5-era,
//! generalizing PR 2's uniform-rate fast-forward):
//!
//! **Multi-rate steady-state fast-forward.** Window dataflow with fixed
//! service times is a max-plus linear system: after warm-up each
//! weakly-connected component settles into a periodic regime. PR 2 could
//! only detect the uniform special case (every node's consecutive
//! inter-finish deltas constant); rate-mismatched regions — gemv's
//! re-read `x` edge fires once per `n/16` kernel iterations, and
//! back-pressure propagates that hiccup into every mover — never
//! stabilized and ran iteration by iteration. The generalized detector
//! tracks each node at its *hyperperiod* `p_i = iters_i / g` (derived in
//! [`super::prepare`]; `g` is the component gcd over participating node
//! iteration counts and edge window counts): a node is periodic when
//! `finish(k) − finish(k − p_i)` has stayed constant for `2·p_i +
//! 2·EDGE_CAPACITY + 2` consecutive iterations, i.e. its finish times fit
//! `t0 + j·Δ` per hyperperiod slot. Low-rate nodes that never complete
//! enough iterations to build that window (the `x` mover finishes once
//! per hyperperiod) join as *slaved* nodes — a few consecutive matching
//! deltas measured inside a regime confirmed by at least one fully
//! windowed **anchor** node. A jump of `m` hyperperiods advances
//! node `i` by `m·p_i` iterations and translates its timestamps by
//! `m·Δ_i` (the Δ's agree across the component — checked); every
//! translating edge fires exactly `m·w/g` tokens on both sides, and its
//! stride accumulators return to their starting values because
//! `p_i·w ≡ 0 (mod iters_i)` by construction. Sporadic edges (scalar
//! streams, anything firing rarer than [`super::PERIOD_CAP`]) are instead
//! kept *silent*: `m` is bounded so no such edge fires inside the skipped
//! window, and the final iterations are always simulated normally.
//!
//! **Parallel component simulation.** No edge crosses a weakly-connected
//! component, so components are independent sub-simulations. The
//! partition is computed once per plan in [`super::prepare`]; `run` fans
//! the components over `util::threadpool` workers and merges
//! per-component results **in component order**, so reports (and traces,
//! which are sorted by start time) are bit-identical for every thread
//! count — parallelism only changes which host thread runs which
//! component. Fast-forward is disabled while tracing (every span must be
//! recorded); parallel execution is not.

use std::collections::VecDeque;

use super::{trace, Prep, EDGE_CAPACITY};
use crate::graph::place::{Location, Placement};
use crate::graph::Graph;
use crate::{Error, Result};

/// Consecutive constant period-deltas required *beyond* two hyperperiods
/// before a node counts as periodic: a full `EDGE_CAPACITY` ping-pong
/// cycle on both sides of the node, plus margin against warm-up
/// coincidences. The full requirement for a node with period `p` is
/// `2·p + STABLE_MARGIN` consecutive good measurements.
const STABLE_MARGIN: u32 = 2 * EDGE_CAPACITY as u32 + 2;

/// Relative tolerance when comparing inter-finish deltas (they differ by
/// a few ulps between iterations because the absolute times grow).
const DELTA_RTOL: f64 = 1e-9;

/// Smallest mean per-node jump (iterations) worth the O(nodes + edges)
/// bookkeeping of a shift.
const MIN_FF_ITERS: usize = 4;

/// Consecutive constant period-deltas that qualify a *slaved* node — one
/// whose total iteration count is provably too small to ever build the
/// full stability window while a jump remains possible (gemv's `x` mover
/// completes one iteration per component hyperperiod). Only applies in
/// multi-rate mode, only alongside an anchor node that carries the full
/// window, and only when the delta matches the anchors'; every node that
/// *could* build the full window must do so.
const WEAK_STABLE: u32 = 2;

/// Below this many total iterations in a graph, scoped-thread fan-out
/// (~10 µs per spawn) costs more than the event loop itself.
const PARALLEL_MIN_ITERS: usize = 8192;

fn stable_needed(period: usize) -> u32 {
    2 * period as u32 + STABLE_MARGIN
}

/// Translate a timestamp ring by `delta` seconds while advancing its
/// token index by `tokens`: slot `t % EDGE_CAPACITY` must afterwards hold
/// the (translated) timestamp of token `t + tokens`, which is a rotation
/// of the ring — so jumps need no alignment to whole ring cycles.
fn shift_ring(ring: &mut [f64; EDGE_CAPACITY], tokens: usize, delta: f64) {
    let rot = tokens % EDGE_CAPACITY;
    if rot != 0 {
        ring.rotate_right(rot);
    }
    for t in ring.iter_mut() {
        *t += delta;
    }
}

/// Fixed-size per-edge state: token counts, stride accumulators, and the
/// last `EDGE_CAPACITY` timestamps on each side. This is the entire
/// memory the engine keeps per edge, independent of the window count.
struct EdgeState {
    /// Tokens produced so far (also: the next token index the producer
    /// will emit).
    produced: usize,
    /// Tokens consumed so far (also: the next token index the consumer
    /// will read).
    consumed: usize,
    /// Arrival times (at the consumer) of tokens
    /// `produced - EDGE_CAPACITY .. produced`, indexed `t % EDGE_CAPACITY`.
    produced_t: [f64; EDGE_CAPACITY],
    /// Finish times of the consumer for tokens
    /// `consumed - EDGE_CAPACITY .. consumed`, indexed `t % EDGE_CAPACITY`.
    consumed_t: [f64; EDGE_CAPACITY],
    /// Producer-side stride accumulator (invariant: `0 ≤ acc < iters`).
    src_acc: usize,
    /// Consumer-side stride accumulator.
    dst_acc: usize,
}

/// Simulation state of ONE weakly-connected component, densely indexed by
/// the component-local node/edge ids from [`super::Components`]. Keeping
/// the state per component (rather than one global `EngineState`) is what
/// lets independent components run on different threads with zero
/// sharing — and it shrinks the warm cache footprint of small components.
struct CompState {
    /// Completed iterations per local node.
    done: Vec<usize>,
    busy_until: Vec<f64>,
    busy_total: Vec<f64>,
    /// Most recent `finish(k) − finish(k − p)` measurement (−1 until two
    /// same-slot finishes exist). For uniform nodes (`p = 1`) this is the
    /// plain inter-finish delta.
    period_delta: Vec<f64>,
    /// Consecutive iterations with an (approximately) unchanged
    /// period-delta.
    stable: Vec<u32>,
    /// Flat finish-time history rings, one ring of length `period[i]`
    /// per local node at `hist_off[i]` — slot `k % p` holds `finish(k)`,
    /// so it still holds `finish(k − p)` right before iteration `k`
    /// finishes.
    hist: Vec<f64>,
    hist_off: Vec<usize>,
    edges: Vec<EdgeState>,
    completed: usize,
}

impl CompState {
    fn new(prep: &Prep, c: usize) -> CompState {
        let nodes = &prep.comp.nodes[c];
        let mut hist_off = Vec::with_capacity(nodes.len());
        let mut hist_len = 0usize;
        for &gid in nodes {
            hist_off.push(hist_len);
            hist_len += prep.period[gid].max(1);
        }
        CompState {
            done: vec![0; nodes.len()],
            busy_until: vec![0.0; nodes.len()],
            busy_total: vec![0.0; nodes.len()],
            period_delta: vec![-1.0; nodes.len()],
            stable: vec![0; nodes.len()],
            hist: vec![0.0; hist_len],
            hist_off,
            edges: (0..prep.comp.edges[c].len())
                .map(|_| EdgeState {
                    produced: 0,
                    consumed: 0,
                    produced_t: [0.0; EDGE_CAPACITY],
                    consumed_t: [0.0; EDGE_CAPACITY],
                    src_acc: 0,
                    dst_acc: 0,
                })
                .collect(),
            completed: 0,
        }
    }
}

/// Counters describing how much work the fast-forward saved (exposed to
/// in-crate tests so a silently-disengaged fast-forward fails loudly).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct EngineStats {
    /// Closed-form jumps taken.
    pub(crate) ff_jumps: usize,
    /// Node-iterations advanced in closed form (not event-simulated).
    pub(crate) ff_iters: usize,
    /// Weakly-connected components simulated.
    pub(crate) components: usize,
}

/// One component's finished simulation, merged by [`run`].
struct CompOutcome {
    makespan: f64,
    /// Busy seconds per local node.
    busy: Vec<f64>,
    ff_jumps: usize,
    ff_iters: usize,
    spans: Vec<trace::Span>,
}

/// Earliest start time of node `gid`'s next iteration, or `None` while a
/// dependency (input token or output buffer space) is missing. Pure: the
/// commit happens in the component loop. `l` is the component-local id.
fn can_start(st: &CompState, prep: &Prep, gid: usize, l: usize) -> Option<f64> {
    let sched = &prep.sched[gid];
    let k = st.done[l];
    let iters = sched.iters;
    let mut start = if k == 0 { sched.launch_s } else { st.busy_until[l] };
    for &eid in &prep.in_adj[gid] {
        let w = prep.edge_windows[eid];
        let es = &st.edges[prep.comp.edge_local[eid]];
        if es.dst_acc + w >= iters {
            // this iteration consumes token `es.consumed`.
            if es.produced <= es.consumed {
                return None;
            }
            start = start.max(es.produced_t[es.consumed % EDGE_CAPACITY]);
        }
    }
    for &eid in &prep.out_adj[gid] {
        let w = prep.edge_windows[eid];
        let es = &st.edges[prep.comp.edge_local[eid]];
        if es.src_acc + w >= iters {
            // this iteration produces token `es.produced`; space frees
            // when the consumer finishes token `produced - EDGE_CAPACITY`.
            let t = es.produced;
            if t >= EDGE_CAPACITY {
                if es.consumed + EDGE_CAPACITY <= t {
                    return None;
                }
                start = start.max(es.consumed_t[(t - EDGE_CAPACITY) % EDGE_CAPACITY]);
            }
        }
    }
    Some(start)
}

/// Try to advance component `c` in closed form by `m` hyperperiods.
/// Returns true when a jump was taken.
fn fast_forward(
    st: &mut CompState,
    prep: &Prep,
    graph: &Graph,
    c: usize,
    ff_jumps: &mut usize,
    ff_iters: &mut usize,
) -> bool {
    let nodes = &prep.comp.nodes[c];
    let comp_edges = &prep.comp.edges[c];

    // --- collect the advancing set ---------------------------------------
    // Pass 1 — **anchors**: nodes with the full stability window at their
    // hyperperiod. They attest the component has been in its periodic
    // regime for ≥ 2 hyperperiods and must agree on the period-delta.
    let mut advancing: Vec<usize> = Vec::new();
    let mut adv = vec![false; nodes.len()];
    let mut unit_s = -1.0f64;
    for (l, &gid) in nodes.iter().enumerate() {
        if st.done[l] >= prep.sched[gid].iters {
            continue;
        }
        let p = prep.period[gid];
        if p > 0 && st.stable[l] >= stable_needed(p) {
            let d = st.period_delta[l];
            if unit_s < 0.0 {
                unit_s = d;
            } else if (d - unit_s).abs() > DELTA_RTOL * d.abs().max(unit_s.abs()) {
                return false; // the component disagrees on its period
            }
            advancing.push(l);
            adv[l] = true;
        }
    }
    if advancing.is_empty() {
        return false; // no anchor: the regime is not confirmed yet
    }
    // Pass 2 — remaining active nodes. Every one must be
    //  (a) **slaved**: a low-rate node phase-locked to the anchors — gemv's
    //      `x` mover produces one token per hyperperiod, so it finishes too
    //      few iterations to ever build the full window; a handful of
    //      consecutive period-deltas matching the anchors' (measured inside
    //      the anchor-confirmed regime) locks it in. The shortcut is
    //      restricted to nodes that provably CANNOT reach the full window
    //      while a jump is still possible: bound (a) needs `done ≤ iters −
    //      p − 1`, measurements start at iteration `p` and the first one
    //      only seeds `period_delta`, so the stable counter can reach at
    //      most `iters − 2p − 2` — the full window is reachable only when
    //      `iters ≥ stable_needed + 2p + 2`; every node at or above that
    //      must earn it like an anchor. Or
    //  (b) genuinely blocked — its dependencies are frozen for the whole
    //      window (the m-bounds below keep every edge it touches silent),
    //      so it stays blocked and is left untouched.
    // An aperiodic node that could still run would be skipped over by a
    // jump: bail.
    for (l, &gid) in nodes.iter().enumerate() {
        if adv[l] || st.done[l] >= prep.sched[gid].iters {
            continue;
        }
        let p = prep.period[gid];
        let never_full_window = prep.multirate
            && p > 0
            && prep.sched[gid].iters < stable_needed(p) as usize + 2 * p + 2;
        if never_full_window && st.stable[l] >= WEAK_STABLE {
            let d = st.period_delta[l];
            if (d - unit_s).abs() <= DELTA_RTOL * d.abs().max(unit_s.abs()) {
                advancing.push(l);
                adv[l] = true;
                continue;
            }
        }
        if can_start(st, prep, gid, l).is_some() {
            return false;
        }
    }

    // --- bound the jump length m (in hyperperiods) ------------------------
    // (a) every advancing node keeps ≥ 1 iteration to simulate (final
    //     iterations fire the sporadic edges, e.g. scalar result streams);
    let mut m = usize::MAX;
    let mut sum_adv = 0usize;
    for &l in &advancing {
        let p = prep.period[nodes[l]];
        m = m.min((prep.sched[nodes[l]].iters - st.done[l] - 1) / p);
        sum_adv += p;
    }
    // (b) classify edges: an edge whose firing pattern is part of the
    //     measured periodicity (`unit_tokens > 0`) and whose endpoints
    //     both advance *translates* with the jump (no bound — the ring
    //     rotation in `shift_ring` absorbs any token advance). Any other
    //     edge side touching an advancing node must stay silent (no fire)
    //     inside the window, which bounds m by its next-fire distance in
    //     hyperperiods.
    for &eid in comp_edges {
        let e = &graph.edges[eid];
        let (ls, ld) = (prep.comp.node_local[e.src], prep.comp.node_local[e.dst]);
        if !adv[ls] && !adv[ld] {
            continue;
        }
        let w = prep.edge_windows[eid];
        if w == 0 {
            continue; // degenerate zero-token edge: never fires
        }
        if prep.unit_tokens[eid] > 0 && adv[ls] && adv[ld] {
            continue; // translates with the jump
        }
        let es = &st.edges[prep.comp.edge_local[eid]];
        if adv[ls] {
            let a = prep.period[nodes[ls]] * w; // accumulator advance per hyperperiod
            m = m.min((prep.sched[e.src].iters - es.src_acc - 1) / a);
        }
        if adv[ld] {
            let a = prep.period[nodes[ld]] * w;
            m = m.min((prep.sched[e.dst].iters - es.dst_acc - 1) / a);
        }
    }
    if m == 0 || m * sum_adv < MIN_FF_ITERS * advancing.len() {
        return false;
    }

    // --- engage: translate the component by m hyperperiods ----------------
    for &l in &advancing {
        let gid = nodes[l];
        let p = prep.period[gid];
        let shift = m as f64 * st.period_delta[l];
        st.done[l] += m * p;
        st.busy_until[l] += shift;
        st.busy_total[l] += (m * p) as f64 * prep.sched[gid].service_s;
        let off = st.hist_off[l];
        for h in &mut st.hist[off..off + p] {
            *h += shift;
        }
        st.completed += m * p;
    }
    for &eid in comp_edges {
        let e = &graph.edges[eid];
        let (ls, ld) = (prep.comp.node_local[e.src], prep.comp.node_local[e.dst]);
        if !adv[ls] && !adv[ld] {
            continue;
        }
        let w = prep.edge_windows[eid];
        if w == 0 {
            continue;
        }
        let t = prep.unit_tokens[eid];
        let le = prep.comp.edge_local[eid];
        if t > 0 && adv[ls] && adv[ld] {
            // translating edge: both sides fire m·t tokens; each side's
            // timestamps shift by its own node's translation, and the
            // rings rotate with the token advance.
            let ds = m as f64 * st.period_delta[ls];
            let dd = m as f64 * st.period_delta[ld];
            let es = &mut st.edges[le];
            es.produced += m * t;
            es.consumed += m * t;
            shift_ring(&mut es.produced_t, m * t, ds);
            shift_ring(&mut es.consumed_t, m * t, dd);
        } else {
            // silent edge: accumulators advance without wrapping (the
            // m-bound above guarantees acc stays < iters).
            if adv[ls] {
                st.edges[le].src_acc += m * prep.period[nodes[ls]] * w;
            }
            if adv[ld] {
                st.edges[le].dst_acc += m * prep.period[nodes[ld]] * w;
            }
        }
    }
    *ff_jumps += 1;
    *ff_iters += m * sum_adv;
    true
}

/// Simulate one weakly-connected component to completion. Entirely
/// self-contained: reads only `prep` + `graph` (shared, immutable) and
/// its own state, so components can run on any thread with identical
/// results.
fn run_component(graph: &Graph, prep: &Prep, c: usize, tracing: bool) -> Result<CompOutcome> {
    let nodes = &prep.comp.nodes[c];
    let total = prep.comp.total_iters[c];
    let mut st = CompState::new(prep, c);
    let mut ff_jumps = 0usize;
    let mut ff_iters = 0usize;
    let mut spans: Vec<trace::Span> = Vec::new();

    let mut queue: VecDeque<usize> = (0..nodes.len()).collect();
    let mut in_queue = vec![true; nodes.len()];
    // Fast-forward attempts are O(nodes + edges): amortize to ≤ O(1) per
    // simulated iteration by spacing them at least that far apart.
    let check_interval = (nodes.len() + prep.comp.edges[c].len()).max(64);
    let mut since_check = 0usize;

    while st.completed < total {
        if since_check >= check_interval && !tracing {
            since_check = 0;
            if fast_forward(&mut st, prep, graph, c, &mut ff_jumps, &mut ff_iters) {
                for (l, &gid) in nodes.iter().enumerate() {
                    if st.done[l] < prep.sched[gid].iters && !in_queue[l] {
                        in_queue[l] = true;
                        queue.push_back(l);
                    }
                }
            }
        }
        let Some(l) = queue.pop_front() else {
            return Err(Error::Sim(format!(
                "deadlock: {}/{total} iterations completed",
                st.completed
            )));
        };
        in_queue[l] = false;
        let gid = nodes[l];

        let sched = &prep.sched[gid];
        let iters = sched.iters;
        let period = prep.period[gid];
        let mut advanced = false;
        while st.done[l] < iters {
            let Some(start) = can_start(&st, prep, gid, l) else { break };
            let k = st.done[l];
            let finish = start + sched.service_s;
            st.busy_until[l] = finish;
            st.busy_total[l] += sched.service_s;
            for &eid in &prep.in_adj[gid] {
                let w = prep.edge_windows[eid];
                let es = &mut st.edges[prep.comp.edge_local[eid]];
                es.dst_acc += w;
                if es.dst_acc >= iters {
                    es.dst_acc -= iters;
                    es.consumed_t[es.consumed % EDGE_CAPACITY] = finish;
                    es.consumed += 1;
                }
            }
            for &eid in &prep.out_adj[gid] {
                let w = prep.edge_windows[eid];
                let es = &mut st.edges[prep.comp.edge_local[eid]];
                es.src_acc += w;
                if es.src_acc >= iters {
                    es.src_acc -= iters;
                    es.produced_t[es.produced % EDGE_CAPACITY] = finish + prep.edge_latency[eid];
                    es.produced += 1;
                }
            }
            st.done[l] += 1;
            st.completed += 1;
            since_check += 1;
            advanced = true;

            // periodicity detection at the node's hyperperiod (drives the
            // fast-forward): compare against finish(k − p) from the ring.
            if period > 0 {
                let slot = st.hist_off[l] + k % period;
                let prev_finish = st.hist[slot];
                st.hist[slot] = finish;
                if k >= period {
                    let d = finish - prev_finish;
                    let prev = st.period_delta[l];
                    if prev >= 0.0 && (d - prev).abs() <= DELTA_RTOL * d.abs().max(prev.abs()) {
                        st.stable[l] = st.stable[l].saturating_add(1);
                    } else {
                        st.stable[l] = 0;
                    }
                    st.period_delta[l] = d;
                }
            }

            if tracing {
                spans.push(trace::Span {
                    node: gid,
                    iteration: k,
                    start_s: start,
                    end_s: finish,
                });
            }
        }
        if advanced {
            // completions may have unblocked consumers (new tokens) and
            // producers (freed buffer space).
            for &eid in &prep.out_adj[gid] {
                let d = prep.comp.node_local[graph.edges[eid].dst];
                if !in_queue[d] && st.done[d] < prep.sched[nodes[d]].iters {
                    in_queue[d] = true;
                    queue.push_back(d);
                }
            }
            for &eid in &prep.in_adj[gid] {
                let s = prep.comp.node_local[graph.edges[eid].src];
                if !in_queue[s] && st.done[s] < prep.sched[nodes[s]].iters {
                    in_queue[s] = true;
                    queue.push_back(s);
                }
            }
        }
    }

    // --- conservation checks ----------------------------------------------
    for &eid in &prep.comp.edges[c] {
        let e = &graph.edges[eid];
        let es = &st.edges[prep.comp.edge_local[eid]];
        if es.produced != e.num_windows() || es.consumed != e.num_windows() {
            return Err(Error::Sim(format!(
                "edge {}: {} produced / {} consumed of {} windows",
                e.id,
                es.produced,
                es.consumed,
                e.num_windows()
            )));
        }
    }

    let makespan = st.busy_until.iter().cloned().fold(0.0, f64::max);
    Ok(CompOutcome { makespan, busy: st.busy_total, ff_jumps, ff_iters, spans })
}

/// Run the event-driven simulation: every weakly-connected component
/// independently (on up to `threads` workers), merged deterministically
/// in component order. Returns (makespan, per-node busy seconds,
/// fast-forward stats).
pub(crate) fn run(
    graph: &Graph,
    placement: &Placement,
    prep: &Prep,
    mut tracer: Option<&mut trace::Trace>,
    threads: usize,
) -> Result<(f64, Vec<f64>, EngineStats)> {
    let n = graph.nodes.len();
    let n_comps = prep.comp.count;
    let tracing = tracer.is_some();
    if let Some(t) = tracer.as_deref_mut() {
        // trace labels precomputed once — the old engine rebuilt the lane
        // string with format! on every traced iteration; since PR 5 the
        // label table lives on the trace and spans carry only node ids.
        t.set_labels(
            graph
                .nodes
                .iter()
                .map(|node| {
                    let lane = match placement.of(node.id) {
                        Location::Tile { col, row } => format!("aie({col},{row}) {}", node.name),
                        Location::Shim { col } => format!("shim({col}) {}", node.name),
                        Location::OffChip => node.name.clone(),
                    };
                    (node.name.clone(), lane)
                })
                .collect(),
        );
    }

    let total: usize = prep.comp.total_iters.iter().sum();
    let workers = threads.max(1).min(n_comps.max(1));
    let outcomes: Vec<Result<CompOutcome>> = if workers > 1 && total >= PARALLEL_MIN_ITERS {
        // weight by iteration count so a dominant component (one big gemv
        // next to trivial scalar movers) gets a worker to itself instead
        // of serializing behind contiguous chunk-mates.
        crate::util::threadpool::parallel_map_weighted(
            n_comps,
            workers,
            &prep.comp.total_iters,
            |c| run_component(graph, prep, c, tracing),
        )
    } else {
        (0..n_comps).map(|c| run_component(graph, prep, c, tracing)).collect()
    };

    // --- deterministic merge, in component order --------------------------
    let mut busy_total = vec![0.0f64; n];
    let mut makespan = 0.0f64;
    let mut stats = EngineStats { components: n_comps, ..Default::default() };
    let mut spans: Vec<trace::Span> = Vec::new();
    for (c, outcome) in outcomes.into_iter().enumerate() {
        let out = outcome?; // first failing component (by id) wins
        makespan = makespan.max(out.makespan);
        for (l, &gid) in prep.comp.nodes[c].iter().enumerate() {
            busy_total[gid] = out.busy[l];
        }
        stats.ff_jumps += out.ff_jumps;
        stats.ff_iters += out.ff_iters;
        spans.extend(out.spans);
    }
    if let Some(t) = tracer {
        // global event order across components: by start time, with the
        // (node, iteration) tiebreak keeping the sort total and stable.
        spans.sort_by(|a, b| {
            a.start_s
                .partial_cmp(&b.start_s)
                .expect("span times are finite")
                .then(a.node.cmp(&b.node))
                .then(a.iteration.cmp(&b.iteration))
        });
        t.spans.extend(spans);
    }
    Ok((makespan, busy_total, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_state_is_fixed_size() {
        // the O(1)-memory claim: edge state must not scale with windows.
        assert!(std::mem::size_of::<EdgeState>() <= 8 * (2 + 2 * EDGE_CAPACITY + 2));
    }

    #[test]
    fn stable_window_scales_with_period() {
        // uniform nodes keep (close to) the PR 2 stability window; a
        // period-p node must confirm two whole hyperperiods plus margin.
        assert_eq!(stable_needed(1), 2 + STABLE_MARGIN);
        assert_eq!(stable_needed(64), 128 + STABLE_MARGIN);
    }

    #[test]
    fn shift_ring_rotates_token_indexing() {
        // token t lives at slot t % EDGE_CAPACITY; after advancing by k
        // tokens and delta seconds, slot (t + k) % EDGE_CAPACITY must hold
        // token t's translated timestamp.
        let mut ring = [10.0, 11.0]; // token 0 at slot 0, token 1 at slot 1
        shift_ring(&mut ring, 3, 5.0); // tokens 3 and 4: 4 % 2 = 0, 3 % 2 = 1
        // old token 0 (slot 0) becomes token 3 → slot 1; old token 1 → token 4 → slot 0.
        assert_eq!(ring, [16.0, 15.0]);
        let mut even = [1.0, 2.0];
        shift_ring(&mut even, 4, 0.5);
        assert_eq!(even, [1.5, 2.5]);
    }
}
