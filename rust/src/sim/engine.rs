//! Event-driven simulation engine (DESIGN.md §7).
//!
//! Replaces the worklist-of-rounds reference engine ([`super::naive`])
//! with three structural changes, none of which alter the token-dataflow
//! semantics:
//!
//! 1. **Ready-queue scheduling** — a node is (re)enqueued only when one of
//!    its neighbours completes an iteration (new token, or freed buffer
//!    space); popping a node drains every iteration its dependencies
//!    allow. No full-graph rescans: scheduling work is O(degree) per
//!    completed iteration, amortized O(1) for the bounded-degree graphs
//!    the builder emits.
//! 2. **Ring-buffer edge state** — the producer can run at most
//!    [`EDGE_CAPACITY`] tokens ahead of the consumer (ping-pong
//!    back-pressure), so only the last `EDGE_CAPACITY` produced/consumed
//!    timestamps are ever read. [`EdgeState`] keeps exactly those, in
//!    fixed-size arrays: O(1) memory per edge instead of O(windows).
//! 3. **Incremental stride counters** — rate matching (`W` tokens spread
//!    evenly over `I` iterations) fires token `t` at iteration `k` iff
//!    `⌊(k+1)W/I⌋ > ⌊kW/I⌋`. Since `W ≤ I` for every adjacent edge, the
//!    quotient advances by at most one per step, so an accumulator with
//!    `acc += W; if acc >= I { acc -= I; fire }` replaces both divisions
//!    of the old `token_at`.
//!
//! On top of the event loop sits a **steady-state fast-forward**: once
//! every still-active node of a weakly-connected component has shown a
//! constant inter-finish delta for `2·EDGE_CAPACITY + 2` consecutive
//! iterations (and the deltas agree across the component), the pipeline
//! is in its periodic regime and iteration `k+m` is iteration `k`
//! translated by `m·Δ`. The engine then advances all those nodes `m`
//! iterations in closed form — counts bumped, ring timestamps shifted by
//! `m·Δ` — instead of simulating `m` rounds of token events. `m` is
//! bounded so that no rate-mismatched edge (e.g. the scalar alpha stream,
//! consumed on the kernel's final iteration) fires inside the skipped
//! window, and the final iterations are always simulated normally.
//! Fast-forward is disabled while tracing (every span must be recorded)
//! and never engages on non-uniform-rate regions (e.g. gemv's re-read x
//! edge), which simply run through the event loop.

use std::collections::VecDeque;

use super::{trace, Prep, EDGE_CAPACITY};
use crate::graph::place::{Location, Placement};
use crate::graph::Graph;
use crate::{Error, Result};

/// Consecutive constant inter-finish deltas required before a node counts
/// as periodic: a full `EDGE_CAPACITY` ping-pong cycle on both sides of
/// the node, plus margin against warm-up coincidences.
const STABLE_WINDOW: u32 = 2 * EDGE_CAPACITY as u32 + 2;

/// Relative tolerance when comparing inter-finish deltas (they differ by
/// a few ulps between iterations because the absolute times grow).
const DELTA_RTOL: f64 = 1e-9;

/// Smallest jump worth the O(nodes + edges) bookkeeping of a shift.
const MIN_FF_ITERS: usize = 4;

/// Fixed-size per-edge state: token counts, stride accumulators, and the
/// last `EDGE_CAPACITY` timestamps on each side. This is the entire
/// memory the engine keeps per edge, independent of the window count.
struct EdgeState {
    /// Tokens produced so far (also: the next token index the producer
    /// will emit).
    produced: usize,
    /// Tokens consumed so far (also: the next token index the consumer
    /// will read).
    consumed: usize,
    /// Arrival times (at the consumer) of tokens
    /// `produced - EDGE_CAPACITY .. produced`, indexed `t % EDGE_CAPACITY`.
    produced_t: [f64; EDGE_CAPACITY],
    /// Finish times of the consumer for tokens
    /// `consumed - EDGE_CAPACITY .. consumed`, indexed `t % EDGE_CAPACITY`.
    consumed_t: [f64; EDGE_CAPACITY],
    /// Producer-side stride accumulator (invariant: `0 ≤ acc < iters`).
    src_acc: usize,
    /// Consumer-side stride accumulator.
    dst_acc: usize,
}

struct EngineState {
    done: Vec<usize>,
    busy_until: Vec<f64>,
    busy_total: Vec<f64>,
    /// Finish time of the node's most recent iteration.
    last_finish: Vec<f64>,
    /// Most recent inter-finish delta (-1.0 until two iterations exist).
    last_delta: Vec<f64>,
    /// Consecutive iterations with an (approximately) unchanged delta.
    stable: Vec<u32>,
    edges: Vec<EdgeState>,
    completed: usize,
}

/// Counters describing how much work the fast-forward saved (exposed to
/// in-crate tests so a silently-disengaged fast-forward fails loudly).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct EngineStats {
    /// Closed-form jumps taken.
    pub(crate) ff_jumps: usize,
    /// Node-iterations advanced in closed form (not event-simulated).
    pub(crate) ff_iters: usize,
}

impl EngineState {
    fn new(nodes: usize, edges: usize) -> Self {
        EngineState {
            done: vec![0; nodes],
            busy_until: vec![0.0; nodes],
            busy_total: vec![0.0; nodes],
            last_finish: vec![0.0; nodes],
            last_delta: vec![-1.0; nodes],
            stable: vec![0; nodes],
            edges: (0..edges)
                .map(|_| EdgeState {
                    produced: 0,
                    consumed: 0,
                    produced_t: [0.0; EDGE_CAPACITY],
                    consumed_t: [0.0; EDGE_CAPACITY],
                    src_acc: 0,
                    dst_acc: 0,
                })
                .collect(),
            completed: 0,
        }
    }
}

/// Earliest start time of node `id`'s next iteration, or `None` while a
/// dependency (input token or output buffer space) is missing. Pure: the
/// commit happens in the main loop.
fn can_start(st: &EngineState, prep: &Prep, id: usize) -> Option<f64> {
    let sched = &prep.sched[id];
    let k = st.done[id];
    let iters = sched.iters;
    let mut start = if k == 0 { sched.launch_s } else { st.busy_until[id] };
    for &eid in &prep.in_adj[id] {
        let w = prep.edge_windows[eid];
        let es = &st.edges[eid];
        if es.dst_acc + w >= iters {
            // this iteration consumes token `es.consumed`.
            if es.produced <= es.consumed {
                return None;
            }
            start = start.max(es.produced_t[es.consumed % EDGE_CAPACITY]);
        }
    }
    for &eid in &prep.out_adj[id] {
        let w = prep.edge_windows[eid];
        let es = &st.edges[eid];
        if es.src_acc + w >= iters {
            // this iteration produces token `es.produced`; space frees
            // when the consumer finishes token `produced - EDGE_CAPACITY`.
            let t = es.produced;
            if t >= EDGE_CAPACITY {
                if es.consumed + EDGE_CAPACITY <= t {
                    return None;
                }
                start = start.max(es.consumed_t[(t - EDGE_CAPACITY) % EDGE_CAPACITY]);
            }
        }
    }
    Some(start)
}

/// Weakly-connected components over the dataflow edges (fast-forward
/// regions). Returns per-node component ids and the component count.
fn components(graph: &Graph) -> (Vec<usize>, usize) {
    fn find(parent: &mut [usize], mut x: usize) -> usize {
        while parent[x] != x {
            parent[x] = parent[parent[x]];
            x = parent[x];
        }
        x
    }
    let n = graph.nodes.len();
    let mut parent: Vec<usize> = (0..n).collect();
    for e in &graph.edges {
        let (a, b) = (find(&mut parent, e.src), find(&mut parent, e.dst));
        if a != b {
            parent[a] = b;
        }
    }
    let mut label = vec![usize::MAX; n];
    let mut count = 0;
    let mut comp = vec![0usize; n];
    for id in 0..n {
        let root = find(&mut parent, id);
        if label[root] == usize::MAX {
            label[root] = count;
            count += 1;
        }
        comp[id] = label[root];
    }
    (comp, count)
}

/// Try to advance every strongly-periodic component in closed form.
/// Returns true when at least one component jumped.
fn fast_forward(
    st: &mut EngineState,
    prep: &Prep,
    graph: &Graph,
    comp: &[usize],
    n_comps: usize,
    stats: &mut EngineStats,
) -> bool {
    let n = prep.sched.len();
    let mut adv = vec![false; n];
    let mut is_shift = vec![false; graph.edges.len()];
    let mut any = false;

    'comps: for c in 0..n_comps {
        let mut advancing: Vec<usize> = Vec::new();
        let mut delta0 = -1.0f64;
        for id in 0..n {
            if comp[id] != c || st.done[id] >= prep.sched[id].iters {
                continue;
            }
            if st.stable[id] >= STABLE_WINDOW {
                // periodic: delta must match the rest of the component.
                let d = st.last_delta[id];
                if delta0 < 0.0 {
                    delta0 = d;
                } else if (d - delta0).abs() > DELTA_RTOL * delta0.abs().max(d.abs()) {
                    continue 'comps;
                }
                advancing.push(id);
            } else if can_start(st, prep, id).is_some() {
                // an aperiodic node that could still run would be skipped
                // over by a jump — the region is not in steady state.
                continue 'comps;
            }
            // else: genuinely blocked; its dependencies are frozen for the
            // whole window (the m-bounds below keep every edge it touches
            // silent), so it stays blocked and is left untouched.
        }
        if advancing.is_empty() {
            continue;
        }
        for &id in &advancing {
            adv[id] = true;
        }

        // --- bound the jump length m ------------------------------------
        // (a) every advancing node keeps ≥ 1 iteration to simulate (final
        //     iterations fire the sporadic edges, e.g. scalar streams);
        let mut m = usize::MAX;
        for &id in &advancing {
            m = m.min(prep.sched[id].iters - st.done[id] - 1);
        }
        // (b) classify edges: uniform-rate edges between two advancing
        //     nodes translate with the jump; any other edge side touching
        //     an advancing node must stay silent (no fire) inside the
        //     window, which bounds m by its next-fire distance.
        let mut shiftable: Vec<usize> = Vec::new();
        for e in &graph.edges {
            if comp[e.src] != c || (!adv[e.src] && !adv[e.dst]) {
                continue;
            }
            let w = prep.edge_windows[e.id];
            if adv[e.src]
                && adv[e.dst]
                && w == prep.sched[e.src].iters
                && w == prep.sched[e.dst].iters
            {
                shiftable.push(e.id);
                continue;
            }
            if w == 0 {
                continue; // degenerate zero-token edge: never fires
            }
            let es = &st.edges[e.id];
            if adv[e.src] {
                m = m.min((prep.sched[e.src].iters - es.src_acc).div_ceil(w) - 1);
            }
            if adv[e.dst] {
                m = m.min((prep.sched[e.dst].iters - es.dst_acc).div_ceil(w) - 1);
            }
        }
        // ring indices are token % EDGE_CAPACITY: jump in whole cycles so
        // the index mapping is preserved.
        let m = m.saturating_sub(m % EDGE_CAPACITY);
        if m < MIN_FF_ITERS {
            for &id in &advancing {
                adv[id] = false;
            }
            continue;
        }

        // --- engage: translate the component by m iterations -------------
        for &id in &advancing {
            let shift = m as f64 * st.last_delta[id];
            st.done[id] += m;
            st.busy_until[id] += shift;
            st.busy_total[id] += m as f64 * prep.sched[id].service_s;
            st.last_finish[id] += shift;
            st.completed += m;
        }
        for &eid in &shiftable {
            is_shift[eid] = true;
            let e = &graph.edges[eid];
            let ds = m as f64 * st.last_delta[e.src];
            let dd = m as f64 * st.last_delta[e.dst];
            let es = &mut st.edges[eid];
            es.produced += m;
            es.consumed += m;
            for t in es.produced_t.iter_mut() {
                *t += ds;
            }
            for t in es.consumed_t.iter_mut() {
                *t += dd;
            }
        }
        for e in &graph.edges {
            if comp[e.src] != c || is_shift[e.id] {
                continue;
            }
            let w = prep.edge_windows[e.id];
            if adv[e.src] {
                st.edges[e.id].src_acc += m * w; // silent: stays < iters
            }
            if adv[e.dst] {
                st.edges[e.id].dst_acc += m * w;
            }
        }
        for &id in &advancing {
            adv[id] = false;
        }
        stats.ff_jumps += 1;
        stats.ff_iters += m * advancing.len();
        any = true;
    }
    any
}

/// Run the event-driven simulation. Returns (makespan, per-node busy
/// seconds, fast-forward stats).
pub(crate) fn run(
    graph: &Graph,
    placement: &Placement,
    prep: &Prep,
    mut tracer: Option<&mut trace::Trace>,
) -> Result<(f64, Vec<f64>, EngineStats)> {
    let n = graph.nodes.len();
    let total: usize = prep.sched.iter().map(|s| s.iters).sum();
    let mut st = EngineState::new(n, graph.edges.len());
    let mut stats = EngineStats::default();
    let (comp, n_comps) = components(graph);

    // Trace labels precomputed once — the old engine rebuilt the lane
    // string with format! on every traced iteration.
    let labels: Option<Vec<(String, String)>> = tracer.as_ref().map(|_| {
        graph
            .nodes
            .iter()
            .map(|node| {
                let lane = match placement.of(node.id) {
                    Location::Tile { col, row } => format!("aie({col},{row}) {}", node.name),
                    Location::Shim { col } => format!("shim({col}) {}", node.name),
                    Location::OffChip => node.name.clone(),
                };
                (node.name.clone(), lane)
            })
            .collect()
    });

    let mut queue: VecDeque<usize> = (0..n).collect();
    let mut in_queue = vec![true; n];
    // Fast-forward attempts are O(nodes + edges): amortize to ≤ O(1) per
    // simulated iteration by spacing them at least that far apart.
    let check_interval = (n + graph.edges.len()).max(64);
    let mut since_check = 0usize;

    while st.completed < total {
        if since_check >= check_interval && tracer.is_none() {
            since_check = 0;
            if fast_forward(&mut st, prep, graph, &comp, n_comps, &mut stats) {
                for (id, s) in prep.sched.iter().enumerate() {
                    if st.done[id] < s.iters && !in_queue[id] {
                        in_queue[id] = true;
                        queue.push_back(id);
                    }
                }
            }
        }
        let Some(id) = queue.pop_front() else {
            return Err(Error::Sim(format!(
                "deadlock: {}/{total} iterations completed",
                st.completed
            )));
        };
        in_queue[id] = false;

        let sched = &prep.sched[id];
        let iters = sched.iters;
        let mut advanced = false;
        while st.done[id] < iters {
            let Some(start) = can_start(&st, prep, id) else { break };
            let k = st.done[id];
            let finish = start + sched.service_s;
            st.busy_until[id] = finish;
            st.busy_total[id] += sched.service_s;
            for &eid in &prep.in_adj[id] {
                let w = prep.edge_windows[eid];
                let es = &mut st.edges[eid];
                es.dst_acc += w;
                if es.dst_acc >= iters {
                    es.dst_acc -= iters;
                    es.consumed_t[es.consumed % EDGE_CAPACITY] = finish;
                    es.consumed += 1;
                }
            }
            for &eid in &prep.out_adj[id] {
                let w = prep.edge_windows[eid];
                let es = &mut st.edges[eid];
                es.src_acc += w;
                if es.src_acc >= iters {
                    es.src_acc -= iters;
                    es.produced_t[es.produced % EDGE_CAPACITY] = finish + prep.edge_latency[eid];
                    es.produced += 1;
                }
            }
            st.done[id] += 1;
            st.completed += 1;
            since_check += 1;
            advanced = true;

            // periodicity detection (drives the fast-forward).
            let delta = finish - st.last_finish[id];
            let prev = st.last_delta[id];
            if prev >= 0.0 && (delta - prev).abs() <= DELTA_RTOL * delta.abs().max(prev.abs()) {
                st.stable[id] = st.stable[id].saturating_add(1);
            } else {
                st.stable[id] = 0;
            }
            st.last_delta[id] = delta;
            st.last_finish[id] = finish;

            if let Some(t) = tracer.as_deref_mut() {
                let (name, lane) = &labels.as_ref().unwrap()[id];
                t.record(trace::Span {
                    node: id,
                    name: name.clone(),
                    lane: lane.clone(),
                    iteration: k,
                    start_s: start,
                    end_s: finish,
                });
            }
        }
        if advanced {
            // completions may have unblocked consumers (new tokens) and
            // producers (freed buffer space).
            for &eid in &prep.out_adj[id] {
                let d = graph.edges[eid].dst;
                if !in_queue[d] && st.done[d] < prep.sched[d].iters {
                    in_queue[d] = true;
                    queue.push_back(d);
                }
            }
            for &eid in &prep.in_adj[id] {
                let s = graph.edges[eid].src;
                if !in_queue[s] && st.done[s] < prep.sched[s].iters {
                    in_queue[s] = true;
                    queue.push_back(s);
                }
            }
        }
    }

    // --- conservation checks ------------------------------------------------
    for e in &graph.edges {
        let es = &st.edges[e.id];
        if es.produced != e.num_windows() || es.consumed != e.num_windows() {
            return Err(Error::Sim(format!(
                "edge {}: {} produced / {} consumed of {} windows",
                e.id,
                es.produced,
                es.consumed,
                e.num_windows()
            )));
        }
    }

    let makespan = st.busy_until.iter().cloned().fold(0.0, f64::max);
    Ok((makespan, st.busy_total, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_state_is_fixed_size() {
        // the O(1)-memory claim: edge state must not scale with windows.
        assert!(std::mem::size_of::<EdgeState>() <= 8 * (2 + 2 * EDGE_CAPACITY + 2));
    }

    #[test]
    fn components_label_disconnected_pipelines() {
        use crate::blas::PortType;
        use crate::graph::{EdgeKind, NodeKind};
        let mut g = Graph::default();
        let a = g.add_node("a", NodeKind::OnChipSource);
        let b = g.add_node("b", NodeKind::OnChipSink);
        let c = g.add_node("c", NodeKind::OnChipSource);
        let d = g.add_node("d", NodeKind::OnChipSink);
        g.add_edge(a, "out", b, "in", PortType::Vector, EdgeKind::Window, 64, 16);
        g.add_edge(c, "out", d, "in", PortType::Vector, EdgeKind::Window, 64, 16);
        let (comp, n) = components(&g);
        assert_eq!(n, 2);
        assert_eq!(comp[a], comp[b]);
        assert_eq!(comp[c], comp[d]);
        assert_ne!(comp[a], comp[c]);
    }
}
