//! Closed-form makespan prediction for uniform periodic pipelines — the
//! autotuner's microsecond-scale pruning tier (DESIGN.md §11).
//!
//! The DES is exact but costs milliseconds per candidate; most of the
//! candidate space can be ranked without it. For graphs where every
//! steady-state node advances exactly one iteration per component
//! hyperperiod (`period == 1` in [`Prep`] terms — axpy/scal/copy/dot
//! chains, the axpydot dataflow pair), the event engine's recurrences
//! have a closed-form solution:
//!
//! * **steady-state interval** `Δ` per component: the slowest of (a) any
//!   single node's service time and (b) any uniform edge's ping-pong
//!   round trip `(service_src + latency + service_dst) / EDGE_CAPACITY`.
//!   Backpressure cycles spanning k > 1 edges are dominated by their
//!   worst pairwise cycle (each contributes `≤ max_e cycle_e` per
//!   `EDGE_CAPACITY` tokens), so pairwise terms suffice.
//! * **fill time** per node: first-iteration finish, a critical-path
//!   recursion over first-token arrivals in topological order.
//! * a steady-state node finishes iteration `I-1` at `fill + (I-1)·Δ`;
//!   a *transient* node (all incident edges fit the double buffers, so
//!   it drains during warm-up — scalar alpha movers, final-result
//!   sinks) runs its few iterations back-to-back once its last input
//!   lands.
//!
//! The prediction is exact in steady state and off by at most the
//! warm-up/drain transition (O(pipeline depth · Δ)), i.e. a vanishing
//! fraction for iteration counts in the hundreds; the property test
//! below holds it to 5% of the DES. Multi-rate graphs (gemv's row-block
//! re-reads) fall outside the validity condition and return `None` —
//! the tuner then falls back to routing cost + DES.

use super::{Prep, EDGE_CAPACITY};
use crate::graph::Graph;
use crate::pipeline::ExecutablePlan;

/// Predict the DES makespan of `graph` under `prep`'s schedules and
/// latencies. `None` when the graph is outside the model's validity
/// condition (any steady-state node with `period != 1`, any
/// rate-mismatched edge between steady-state nodes, or a cyclic graph).
pub(crate) fn predict(graph: &Graph, prep: &Prep) -> Option<f64> {
    let n = graph.nodes.len();
    if n == 0 {
        return Some(0.0);
    }

    // Transient nodes drain entirely during warm-up: every incident edge
    // fits the ping-pong buffers. Recomputed here rather than read off
    // `prep.period` because a period of 0 also means "beyond PERIOD_CAP",
    // which is *not* transient.
    let mut transient = vec![true; n];
    for e in &graph.edges {
        if prep.edge_windows[e.id] > EDGE_CAPACITY {
            transient[e.src] = false;
            transient[e.dst] = false;
        }
    }

    // Validity: every steady-state node advances one iteration per
    // hyperperiod, and every edge between steady-state nodes is uniform
    // (fires every iteration on both sides). Anything else is multi-rate
    // and needs the DES.
    for id in 0..n {
        if !transient[id] && prep.period[id] != 1 {
            return None;
        }
    }
    for e in &graph.edges {
        if !transient[e.src]
            && !transient[e.dst]
            && (prep.edge_windows[e.id] != prep.sched[e.src].iters
                || prep.edge_windows[e.id] != prep.sched[e.dst].iters)
        {
            return None;
        }
    }

    // Steady-state interval per component.
    let mut delta = vec![0.0f64; prep.comp.count];
    for id in 0..n {
        if !transient[id] {
            let c = prep.comp.of_node[id];
            delta[c] = delta[c].max(prep.sched[id].service_s);
        }
    }
    for e in &graph.edges {
        if !transient[e.src] && !transient[e.dst] {
            let c = prep.comp.of_node[e.src];
            let cycle = (prep.sched[e.src].service_s
                + prep.edge_latency[e.id]
                + prep.sched[e.dst].service_s)
                / EDGE_CAPACITY as f64;
            delta[c] = delta[c].max(cycle);
        }
    }

    let order = topo_order(graph, n)?;

    // fill = first-iteration finish; last = final-iteration finish.
    let mut fill = vec![0.0f64; n];
    let mut last = vec![0.0f64; n];
    for &id in &order {
        let s = &prep.sched[id];
        let mut ready = s.launch_s;
        for &eid in &prep.in_adj[id] {
            let e = &graph.edges[eid];
            // First tokens come off a uniform producer's first iteration,
            // or off a transient producer (which fires immediately).
            if transient[e.src] || prep.edge_windows[eid] == s.iters {
                ready = ready.max(fill[e.src] + prep.edge_latency[eid]);
            }
        }
        fill[id] = ready + s.service_s;

        if transient[id] {
            // Drains back-to-back once its last gating input lands (a
            // scalar-result edge fires on the producer's final iteration).
            let mut start = s.launch_s;
            for &eid in &prep.in_adj[id] {
                let e = &graph.edges[eid];
                start = start.max(last[e.src] + prep.edge_latency[eid]);
            }
            last[id] = start + s.iters as f64 * s.service_s;
        } else {
            let c = prep.comp.of_node[id];
            let mut l = fill[id] + (s.iters as f64 - 1.0) * delta[c];
            // A sparse edge from a transient producer (the alpha stream)
            // gates a late iteration too; its early arrival rarely binds,
            // but keep the bound exact.
            for &eid in &prep.in_adj[id] {
                let e = &graph.edges[eid];
                if transient[e.src] && prep.edge_windows[eid] < s.iters {
                    l = l.max(fill[e.src] + prep.edge_latency[eid] + s.service_s);
                }
            }
            last[id] = l;
        }
    }

    Some(last.iter().fold(0.0f64, |a, &b| a.max(b)))
}

/// Predict a lowered plan's makespan without running the DES. Public
/// entry for the CLI `tune` table and the tune bench; `None` when the
/// plan is outside the analytic model's validity condition.
pub fn predict_plan(plan: &ExecutablePlan) -> Option<f64> {
    let prep = super::prepare(plan.graph(), plan.routing(), plan.arch());
    predict(plan.graph(), &prep)
}

/// Kahn topological order; `None` on a cycle (dataflow graphs are DAGs,
/// but the model must not loop forever on a corrupt one).
fn topo_order(graph: &Graph, n: usize) -> Option<Vec<usize>> {
    let mut indeg = vec![0usize; n];
    for e in &graph.edges {
        indeg[e.dst] += 1;
    }
    let mut stack: Vec<usize> = (0..n).filter(|&id| indeg[id] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(id) = stack.pop() {
        order.push(id);
        for e in graph.out_edges(id) {
            indeg[e.dst] -= 1;
            if indeg[e.dst] == 0 {
                stack.push(e.dst);
            }
        }
    }
    (order.len() == n).then_some(order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::pipeline::lower_spec;
    use crate::sim::{prepare, simulate_plan};
    use crate::spec::{DataSource, Spec};
    use crate::util::proptest::{forall, one_of, pair, usize_in, Config, Gen, Prop};

    /// Lower, predict, and DES-simulate one spec.
    fn predict_and_sim(spec: &Spec) -> (Option<f64>, f64) {
        let plan = lower_spec(spec).unwrap();
        let prep = prepare(plan.graph(), plan.routing(), plan.arch());
        let predicted = predict(plan.graph(), &prep);
        let simulated = simulate_plan(&plan).unwrap().makespan_s;
        (predicted, simulated)
    }

    #[test]
    fn analytic_matches_des_on_uniform_axpy() {
        let mut spec = Spec::single(RoutineKind::Axpy, "a", 1 << 16, DataSource::Pl);
        spec.routines[0].window = Some(128);
        let (p, m) = predict_and_sim(&spec);
        let p = p.expect("axpy is a uniform periodic pipeline");
        assert!((p - m).abs() / m <= 0.05, "predicted {p}, DES {m}");
    }

    #[test]
    fn multirate_gemv_declines_to_predict() {
        // gemv re-reads x every row block — multi-rate, outside the
        // validity condition; the model must say so rather than guess.
        let plan =
            lower_spec(&Spec::single(RoutineKind::Gemv, "g", 512, DataSource::Pl)).unwrap();
        let prep = prepare(plan.graph(), plan.routing(), plan.arch());
        assert_eq!(predict(plan.graph(), &prep), None);
    }

    /// Generator over uniform-rate pipelines with iteration counts in the
    /// hundreds (where the steady state dominates the transition).
    fn uniform_spec_gen() -> Gen<Spec> {
        let kinds = one_of(vec![
            RoutineKind::Axpy,
            RoutineKind::Scal,
            RoutineKind::Copy,
            RoutineKind::Dot,
            RoutineKind::Nrm2,
        ]);
        pair(pair(kinds, usize_in(0, 5)), usize_in(0, 3)).map(|((kind, sel), shape)| {
            let window = if sel % 2 == 0 { 128 } else { 64 };
            match shape {
                0 => {
                    let mut spec = Spec::axpydot_dataflow(1 << 15, 2.0);
                    for r in &mut spec.routines {
                        r.window = Some(window);
                    }
                    spec
                }
                1 => {
                    let mut spec = Spec::chain(RoutineKind::Scal, 3, 1 << 15);
                    for r in &mut spec.routines {
                        r.window = Some(window);
                    }
                    spec
                }
                _ => {
                    let n = if sel < 3 { 1 << 15 } else { 1 << 16 };
                    let source = if sel % 2 == 0 { DataSource::Pl } else { DataSource::OnChip };
                    let mut spec = Spec::single(kind, "k", n, source);
                    spec.routines[0].window = Some(window);
                    spec.routines[0].burst = sel == 1;
                    spec
                }
            }
        })
    }

    #[test]
    fn analytic_tracks_des_within_tolerance_on_uniform_pipelines() {
        forall(&uniform_spec_gen(), Config { cases: 24, ..Default::default() }, |spec| {
            let (predicted, simulated) = predict_and_sim(spec);
            let Some(p) = predicted else {
                return Prop::Fail("uniform-rate spec must be predictable".into());
            };
            let err = (p - simulated).abs() / simulated;
            if err > 0.05 {
                Prop::Fail(format!(
                    "predicted {p}, DES {simulated} ({:.2}% off)",
                    err * 100.0
                ))
            } else {
                Prop::Pass
            }
        });
    }
}
