//! The execution-backend abstraction (DESIGN.md §3).
//!
//! A [`Backend`] consumes an [`ExecutablePlan`] produced by the staged
//! pipeline and executes it: `prepare` binds the plan to the backend
//! (validating that the backend can serve it), `execute` runs the design's
//! routines on concrete inputs. Three implementations ship:
//!
//! * [`SimBackend`] — cycle-approximate VCK5000 timing via `crate::sim`,
//!   with numerics served by the PJRT executor (falling back to the
//!   reference implementations) — the paper's simulated-device series;
//! * [`CpuBackend`] — the threaded CPU BLAS (`crate::blas::cpu`), the
//!   measured OpenBLAS stand-in of Fig. 3;
//! * [`ReferenceBackend`] — the scalar ground-truth kernels
//!   (`crate::blas::reference`) every other backend is validated against.
//!
//! Backends are `Send + Sync` so the serving layer (`crate::serve`) can
//! share one instance across a pool of dispatcher threads. Batched
//! execution ([`Backend::execute_batch`]) amortizes per-plan setup over
//! many requests for the same prepared plan — the simulator runs its DES
//! at most once per batch, reusing a per-plan memo across calls — and
//! [`ShardedBackend`] fans a batch across `util::threadpool` workers.
//!
//! Adding a fourth backend is implementing the three required trait
//! methods — see DESIGN.md §3 for a worked ≤30-line example.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::blas::RoutineKind;
use crate::pipeline::ExecutablePlan;
use crate::runtime::{validate_inputs, NumericExecutor, Provenance};
use crate::sim::SimReport;
use crate::{Error, Result};

/// Per-routine input vectors for one execution, indexed like
/// `plan.spec().routines`. An empty set means "timing only" for backends
/// that can produce timing without data (the simulator).
#[derive(Debug, Clone, Default)]
pub struct ExecInputs {
    pub per_routine: Vec<Vec<Vec<f32>>>,
}

impl ExecInputs {
    /// Deterministic standard-normal inputs for every routine of a spec.
    pub fn random_for(spec: &crate::spec::Spec, seed: u64) -> ExecInputs {
        let mut rng = crate::util::rng::Rng::new(seed);
        let per_routine = spec
            .routines
            .iter()
            .map(|r| {
                r.kind
                    .inputs()
                    .iter()
                    .map(|p| rng.normal_vec_f32(p.ty.elements(r.size)))
                    .collect()
            })
            .collect();
        ExecInputs { per_routine }
    }

    pub fn is_empty(&self) -> bool {
        self.per_routine.is_empty()
    }

    /// Inputs for routine `index`, erroring on arity mismatch.
    fn for_routine(&self, index: usize, name: &str) -> Result<&[Vec<f32>]> {
        self.per_routine
            .get(index)
            .map(Vec::as_slice)
            .ok_or_else(|| Error::Runtime(format!("no inputs provided for routine {name:?}")))
    }
}

/// One routine's execution result. `routine` is a shared interned name
/// ([`Prepared`] builds the `Arc<str>` once per prepare), so per-request
/// results clone a refcount instead of a `String` — the serving warm
/// path allocates nothing for labels.
#[derive(Debug, Clone)]
pub struct RoutineResult {
    pub routine: Arc<str>,
    pub kind: RoutineKind,
    pub output: Vec<f32>,
    /// Which concrete implementation produced the numbers.
    pub provenance: Provenance,
}

/// The outcome of executing a prepared plan on one backend.
#[derive(Debug, Clone)]
pub struct ExecOutcome {
    pub backend: &'static str,
    /// Per-routine outputs (empty for timing-only executions).
    pub results: Vec<RoutineResult>,
    /// Simulated device timing, when the backend models the device.
    pub sim: Option<SimReport>,
    /// Host wallclock spent executing, seconds.
    pub wall_s: f64,
}

/// A plan bound to a backend by [`Backend::prepare`].
#[derive(Debug, Clone)]
pub struct Prepared {
    plan: Arc<ExecutablePlan>,
    backend: &'static str,
    /// Routine names interned once per prepare, indexed like
    /// `plan.spec().routines` — execute paths label results by cloning an
    /// `Arc` instead of allocating a `String` per routine per request.
    names: Vec<Arc<str>>,
}

impl Prepared {
    pub fn new(plan: Arc<ExecutablePlan>, backend: &'static str) -> Prepared {
        let names =
            plan.spec().routines.iter().map(|r| Arc::<str>::from(r.name.as_str())).collect();
        Prepared { plan, backend, names }
    }

    pub fn plan(&self) -> &ExecutablePlan {
        &self.plan
    }

    pub fn plan_arc(&self) -> &Arc<ExecutablePlan> {
        &self.plan
    }

    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// The interned routine names, indexed like `plan.spec().routines`.
    pub fn routine_names(&self) -> &[Arc<str>] {
        &self.names
    }
}

/// An execution target for lowered plans.
///
/// `Send + Sync` is part of the contract: the serving layer dispatches
/// batches to one shared backend from many threads.
pub trait Backend: Send + Sync {
    /// Stable backend name (used in reports and outcome labels).
    fn name(&self) -> &'static str;

    /// Validate that this backend can serve `plan` and bind it.
    fn prepare(&self, plan: Arc<ExecutablePlan>) -> Result<Prepared>;

    /// Execute the prepared plan on `inputs`.
    fn execute(&self, prepared: &Prepared, inputs: &ExecInputs) -> Result<ExecOutcome>;

    /// Execute one prepared plan on many requests' inputs, returning one
    /// outcome per request (in order). The default runs requests
    /// sequentially; backends override it to amortize per-plan setup over
    /// the whole batch. Outputs must be bit-identical to per-request
    /// [`Backend::execute`] calls (enforced by `rust/tests/serving.rs`).
    fn execute_batch(&self, prepared: &Prepared, batch: &[ExecInputs]) -> Vec<Result<ExecOutcome>> {
        batch.iter().map(|inputs| self.execute(prepared, inputs)).collect()
    }
}

fn check_prepared(prepared: &Prepared, backend: &'static str) -> Result<()> {
    if prepared.backend() != backend {
        return Err(Error::Runtime(format!(
            "plan was prepared for backend {:?}, not {backend:?}",
            prepared.backend()
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// SimBackend
// ---------------------------------------------------------------------------

/// The simulated VCK5000: DES timing from `crate::sim`, numerics from the
/// PJRT executor (reference fallback) when one is attached.
///
/// The DES itself scales two ways (DESIGN.md §7): multi-rate steady-state
/// fast-forward advances periodic regions in closed form, and independent
/// weakly-connected components (multi-routine plans, `split` shards) are
/// simulated on parallel workers — so the once-per-batch DES run in
/// [`SimBackend::execute_batch`] already uses the machine's cores without
/// any wrapping. `AIEBLAS_SIM_THREADS` caps the component parallelism.
///
/// Device timing depends only on the plan, never on inputs, so the backend
/// keeps a one-deep per-plan memo of the last [`SimReport`]: repeated
/// `execute` calls and successive batches for the same `Arc`'d plan reuse
/// one DES warm-up instead of re-simulating per call.
pub struct SimBackend<'e> {
    executor: Option<&'e NumericExecutor>,
    /// Last simulated plan (held weakly, which also pins its allocation so
    /// the pointer identity cannot be recycled) and its report.
    sim_memo: Mutex<Option<(std::sync::Weak<ExecutablePlan>, SimReport)>>,
}

impl<'e> SimBackend<'e> {
    /// Timing only: `execute` simulates the device; numeric requests are
    /// served by the reference implementations.
    pub fn timing_only() -> SimBackend<'static> {
        SimBackend { executor: None, sim_memo: Mutex::new(None) }
    }

    /// Numerics flow through `executor` (PJRT artifacts when present).
    pub fn with_executor(executor: &'e NumericExecutor) -> SimBackend<'e> {
        SimBackend { executor: Some(executor), sim_memo: Mutex::new(None) }
    }

    /// Device timing for `prepared`'s plan, served from the memo when this
    /// backend last simulated the same plan (by `Arc` identity).
    fn sim_report(&self, prepared: &Prepared) -> Result<SimReport> {
        let plan_ptr = Arc::as_ptr(prepared.plan_arc());
        if let Some((memo_plan, report)) =
            self.sim_memo.lock().expect("sim memo poisoned").as_ref()
        {
            if std::ptr::eq(memo_plan.as_ptr(), plan_ptr) {
                return Ok(report.clone());
            }
        }
        // simulate outside the lock: a stale memo must not serialize DES
        // runs for unrelated plans (concurrent same-plan callers race to
        // fill the memo, which is merely redundant, not wrong).
        let plan = prepared.plan();
        let report =
            crate::sim::simulate(plan.graph(), plan.placement(), plan.routing(), plan.arch())?;
        *self.sim_memo.lock().expect("sim memo poisoned") =
            Some((Arc::downgrade(prepared.plan_arc()), report.clone()));
        Ok(report)
    }

    /// Execute with trace capture (Chrome-trace / Gantt export).
    pub fn execute_traced(
        &self,
        prepared: &Prepared,
    ) -> Result<(SimReport, crate::sim::trace::Trace)> {
        check_prepared(prepared, self.name())?;
        let plan = prepared.plan();
        crate::sim::simulate_traced(plan.graph(), plan.placement(), plan.routing(), plan.arch())
    }

    fn run_numeric(
        &self,
        name: &str,
        size: usize,
        inputs: &[Vec<f32>],
    ) -> Result<(Vec<f32>, Provenance)> {
        match self.executor {
            Some(ex) => ex.execute(name, size, inputs),
            None => {
                validate_inputs(name, size, inputs)?;
                Ok((ReferenceBackend::execute_named(name, size, inputs)?, Provenance::Reference))
            }
        }
    }

    /// Numeric execution of every routine in the plan (empty inputs mean
    /// timing-only). Shared by `execute` and `execute_batch`.
    fn numeric_results(
        &self,
        prepared: &Prepared,
        inputs: &ExecInputs,
    ) -> Result<Vec<RoutineResult>> {
        let mut results = Vec::new();
        if !inputs.is_empty() {
            let names = prepared.routine_names();
            for (i, r) in prepared.plan().spec().routines.iter().enumerate() {
                let rin = inputs.for_routine(i, &r.name)?;
                let (output, provenance) = self.run_numeric(r.kind.name(), r.size, rin)?;
                results.push(RoutineResult {
                    routine: names[i].clone(),
                    kind: r.kind,
                    output,
                    provenance,
                });
            }
        }
        Ok(results)
    }
}

impl Backend for SimBackend<'_> {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn prepare(&self, plan: Arc<ExecutablePlan>) -> Result<Prepared> {
        // the pipeline guarantees placement + routing; re-assert the cheap
        // conservation invariant so a hand-built plan cannot slip through.
        crate::graph::route::check_routing(plan.graph(), plan.routing())?;
        Ok(Prepared::new(plan, self.name()))
    }

    fn execute(&self, prepared: &Prepared, inputs: &ExecInputs) -> Result<ExecOutcome> {
        check_prepared(prepared, self.name())?;
        let t0 = Instant::now();
        let sim = self.sim_report(prepared)?;
        let results = self.numeric_results(prepared, inputs)?;
        Ok(ExecOutcome {
            backend: self.name(),
            results,
            sim: Some(sim),
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Batched execution amortizes the expensive part: device timing
    /// depends only on the plan, so the DES runs **at most once** per batch
    /// (zero times when the per-plan memo is warm from an earlier call) and
    /// every request shares the report. Each outcome's `wall_s` is that
    /// request's numerics time plus a 1/batch share of the DES (or memo
    /// lookup) time, so summed `wall_s` still accounts for the host work
    /// actually done.
    fn execute_batch(&self, prepared: &Prepared, batch: &[ExecInputs]) -> Vec<Result<ExecOutcome>> {
        if batch.is_empty() {
            return Vec::new();
        }
        let t_sim = Instant::now();
        let sim =
            match check_prepared(prepared, self.name()).and_then(|()| self.sim_report(prepared)) {
            Ok(sim) => sim,
            // errors are per-request values but `Error` is not `Clone`:
            // render once and hand every request the same message rather
            // than re-running the failing DES per request.
            Err(e) => {
                let msg = e.to_string();
                return batch.iter().map(|_| Err(Error::Runtime(msg.clone()))).collect();
            }
        };
        let sim_share_s = t_sim.elapsed().as_secs_f64() / batch.len() as f64;
        batch
            .iter()
            .map(|inputs| {
                let t0 = Instant::now();
                let results = self.numeric_results(prepared, inputs)?;
                Ok(ExecOutcome {
                    backend: self.name(),
                    results,
                    sim: Some(sim.clone()),
                    wall_s: sim_share_s + t0.elapsed().as_secs_f64(),
                })
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// CpuBackend
// ---------------------------------------------------------------------------

/// The threaded CPU BLAS baseline (OpenBLAS stand-in, Fig. 3 "cpu").
pub struct CpuBackend;

impl CpuBackend {
    /// Run one routine on the optimized CPU kernels (inputs in
    /// `RoutineKind::inputs()` order; outputs concatenated like the PJRT
    /// tuple flattening). Output buffers come from the thread-local
    /// `util::pool` — bit-identical to fresh `vec![0.0; n]` allocations.
    pub fn run_kind(kind: RoutineKind, size: usize, inputs: &[Vec<f32>]) -> Vec<f32> {
        use crate::blas::cpu;
        use crate::util::pool;
        let n = size;
        match kind {
            RoutineKind::Axpy => {
                let mut z = pool::take_zeroed(n);
                cpu::axpy(inputs[0][0], &inputs[1], &inputs[2], &mut z);
                z
            }
            RoutineKind::Scal => {
                let mut z = pool::take_zeroed(n);
                cpu::scal(inputs[0][0], &inputs[1], &mut z);
                z
            }
            RoutineKind::Axpby => {
                let mut z = pool::take_zeroed(n);
                cpu::axpby(inputs[0][0], &inputs[2], inputs[1][0], &inputs[3], &mut z);
                z
            }
            RoutineKind::Rot => {
                let mut xo = pool::take_zeroed(n);
                let mut yo = pool::take_zeroed(n);
                cpu::rot(inputs[0][0], inputs[1][0], &inputs[2], &inputs[3], &mut xo, &mut yo);
                xo.extend_from_slice(&yo);
                pool::recycle(yo);
                xo
            }
            RoutineKind::Ger => {
                let mut out = pool::take_zeroed(n * n);
                cpu::ger(inputs[0][0], &inputs[1], &inputs[2], &inputs[3], n, n, &mut out);
                out
            }
            RoutineKind::Copy => pool::take_copied(&inputs[0]),
            RoutineKind::Dot => vec![cpu::dot(&inputs[0], &inputs[1])],
            RoutineKind::Nrm2 => vec![cpu::nrm2(&inputs[0])],
            RoutineKind::Asum => vec![cpu::asum(&inputs[0])],
            RoutineKind::Iamax => vec![cpu::iamax(&inputs[0]) as f32],
            RoutineKind::Gemv => {
                let mut out = pool::take_zeroed(n);
                cpu::gemv(
                    inputs[0][0],
                    &inputs[1],
                    n,
                    n,
                    &inputs[2],
                    inputs[3][0],
                    &inputs[4],
                    &mut out,
                );
                out
            }
            RoutineKind::Gemm => {
                let mut out = pool::take_zeroed(n * n);
                cpu::gemm(
                    inputs[0][0],
                    &inputs[1],
                    &inputs[2],
                    n,
                    n,
                    n,
                    inputs[3][0],
                    &inputs[4],
                    &mut out,
                );
                out
            }
            RoutineKind::Axpydot => {
                vec![cpu::axpydot(inputs[0][0], &inputs[1], &inputs[2], &inputs[3])]
            }
        }
    }

    /// Execute every routine of the prepared plan on `inputs` — shared by
    /// `execute` and `execute_batch` so the two paths cannot diverge.
    fn routine_results(prepared: &Prepared, inputs: &ExecInputs) -> Result<Vec<RoutineResult>> {
        let routines = &prepared.plan().spec().routines;
        let names = prepared.routine_names();
        let mut results = Vec::with_capacity(routines.len());
        for (i, r) in routines.iter().enumerate() {
            let rin = inputs.for_routine(i, &r.name)?;
            validate_inputs(r.kind.name(), r.size, rin)?;
            let output = std::hint::black_box(Self::run_kind(r.kind, r.size, rin));
            results.push(RoutineResult {
                routine: names[i].clone(),
                kind: r.kind,
                output,
                provenance: Provenance::Cpu,
            });
        }
        Ok(results)
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn prepare(&self, plan: Arc<ExecutablePlan>) -> Result<Prepared> {
        Ok(Prepared::new(plan, self.name()))
    }

    fn execute(&self, prepared: &Prepared, inputs: &ExecInputs) -> Result<ExecOutcome> {
        check_prepared(prepared, self.name())?;
        let t0 = Instant::now();
        let results = Self::routine_results(prepared, inputs)?;
        Ok(ExecOutcome {
            backend: self.name(),
            results,
            sim: None,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Batched execution checks the prepared binding once for the whole
    /// batch.
    fn execute_batch(&self, prepared: &Prepared, batch: &[ExecInputs]) -> Vec<Result<ExecOutcome>> {
        if check_prepared(prepared, self.name()).is_err() {
            return batch.iter().map(|inputs| self.execute(prepared, inputs)).collect();
        }
        batch
            .iter()
            .map(|inputs| {
                let t0 = Instant::now();
                let results = Self::routine_results(prepared, inputs)?;
                Ok(ExecOutcome {
                    backend: self.name(),
                    results,
                    sim: None,
                    wall_s: t0.elapsed().as_secs_f64(),
                })
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// ReferenceBackend
// ---------------------------------------------------------------------------

/// The scalar reference implementations — ground truth for every other
/// backend (and the PJRT fallback path).
pub struct ReferenceBackend;

impl ReferenceBackend {
    /// Execute a routine by registry name with flat inputs in artifact
    /// parameter order. Supports the `axpy_neg` artifact alias
    /// (z = w − αv with params (α, v, w)).
    pub fn execute_named(name: &str, size: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        use crate::blas::reference as r;
        use crate::util::pool;
        let n = size;
        let need = |k: usize| -> Result<()> {
            if inputs.len() != k {
                return Err(Error::Runtime(format!(
                    "{name}: expected {k} inputs, got {}",
                    inputs.len()
                )));
            }
            Ok(())
        };
        let kind = RoutineKind::from_name(name.strip_suffix("_neg").unwrap_or(name))
            .or(match name {
                "axpy_neg" => Some(RoutineKind::Axpy),
                _ => None,
            })
            .ok_or_else(|| Error::Runtime(format!("unknown routine {name:?}")))?;
        match (name, kind) {
            ("axpy", _) => {
                need(3)?;
                let mut z = pool::take_zeroed(n);
                r::axpy(inputs[0][0], &inputs[1], &inputs[2], &mut z);
                Ok(z)
            }
            ("axpy_neg", _) => {
                need(3)?;
                let mut z = pool::take_zeroed(n);
                r::axpy(-inputs[0][0], &inputs[1], &inputs[2], &mut z);
                Ok(z)
            }
            (_, RoutineKind::Axpby) => {
                need(4)?;
                let mut z = pool::take_zeroed(n);
                r::axpby(inputs[0][0], &inputs[2], inputs[1][0], &inputs[3], &mut z);
                Ok(z)
            }
            (_, RoutineKind::Rot) => {
                // concatenated outputs (x_out ++ y_out), matching the PJRT
                // tuple flattening.
                need(4)?;
                let mut xo = pool::take_zeroed(n);
                let mut yo = pool::take_zeroed(n);
                r::rot(inputs[0][0], inputs[1][0], &inputs[2], &inputs[3], &mut xo, &mut yo);
                xo.extend_from_slice(&yo);
                pool::recycle(yo);
                Ok(xo)
            }
            (_, RoutineKind::Ger) => {
                need(4)?;
                let mut out = pool::take_zeroed(n * n);
                r::ger(inputs[0][0], &inputs[1], &inputs[2], &inputs[3], n, n, &mut out);
                Ok(out)
            }
            (_, RoutineKind::Scal) => {
                need(2)?;
                let mut z = pool::take_zeroed(n);
                r::scal(inputs[0][0], &inputs[1], &mut z);
                Ok(z)
            }
            (_, RoutineKind::Copy) => {
                need(1)?;
                Ok(pool::take_copied(&inputs[0]))
            }
            (_, RoutineKind::Dot) => {
                need(2)?;
                Ok(vec![r::dot(&inputs[0], &inputs[1])])
            }
            (_, RoutineKind::Nrm2) => {
                need(1)?;
                Ok(vec![r::nrm2(&inputs[0])])
            }
            (_, RoutineKind::Asum) => {
                need(1)?;
                Ok(vec![r::asum(&inputs[0])])
            }
            (_, RoutineKind::Iamax) => {
                need(1)?;
                Ok(vec![r::iamax(&inputs[0]) as f32])
            }
            (_, RoutineKind::Gemv) => {
                need(5)?;
                let mut out = pool::take_zeroed(n);
                r::gemv(
                    inputs[0][0],
                    &inputs[1],
                    n,
                    n,
                    &inputs[2],
                    inputs[3][0],
                    &inputs[4],
                    &mut out,
                );
                Ok(out)
            }
            (_, RoutineKind::Gemm) => {
                need(5)?;
                let mut out = pool::take_zeroed(n * n);
                r::gemm(
                    inputs[0][0],
                    &inputs[1],
                    &inputs[2],
                    n,
                    n,
                    n,
                    inputs[3][0],
                    &inputs[4],
                    &mut out,
                );
                Ok(out)
            }
            (_, RoutineKind::Axpydot) => {
                need(4)?;
                Ok(vec![r::axpydot(inputs[0][0], &inputs[1], &inputs[2], &inputs[3])])
            }
            _ => Err(Error::Runtime(format!("unhandled routine {name:?}"))),
        }
    }

    /// Execute by routine kind.
    pub fn run_kind(kind: RoutineKind, size: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        Self::execute_named(kind.name(), size, inputs)
    }

    /// Execute every routine of the prepared plan on `inputs` — shared by
    /// `execute` and `execute_batch` so the two paths cannot diverge.
    fn routine_results(prepared: &Prepared, inputs: &ExecInputs) -> Result<Vec<RoutineResult>> {
        let routines = &prepared.plan().spec().routines;
        let names = prepared.routine_names();
        let mut results = Vec::with_capacity(routines.len());
        for (i, r) in routines.iter().enumerate() {
            let rin = inputs.for_routine(i, &r.name)?;
            validate_inputs(r.kind.name(), r.size, rin)?;
            let output = Self::run_kind(r.kind, r.size, rin)?;
            results.push(RoutineResult {
                routine: names[i].clone(),
                kind: r.kind,
                output,
                provenance: Provenance::Reference,
            });
        }
        Ok(results)
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn prepare(&self, plan: Arc<ExecutablePlan>) -> Result<Prepared> {
        Ok(Prepared::new(plan, self.name()))
    }

    fn execute(&self, prepared: &Prepared, inputs: &ExecInputs) -> Result<ExecOutcome> {
        check_prepared(prepared, self.name())?;
        let t0 = Instant::now();
        let results = Self::routine_results(prepared, inputs)?;
        Ok(ExecOutcome {
            backend: self.name(),
            results,
            sim: None,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Same amortization as [`CpuBackend::execute_batch`].
    fn execute_batch(&self, prepared: &Prepared, batch: &[ExecInputs]) -> Vec<Result<ExecOutcome>> {
        if check_prepared(prepared, self.name()).is_err() {
            return batch.iter().map(|inputs| self.execute(prepared, inputs)).collect();
        }
        batch
            .iter()
            .map(|inputs| {
                let t0 = Instant::now();
                let results = Self::routine_results(prepared, inputs)?;
                Ok(ExecOutcome {
                    backend: self.name(),
                    results,
                    sim: None,
                    wall_s: t0.elapsed().as_secs_f64(),
                })
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// ShardedBackend
// ---------------------------------------------------------------------------

/// Adapter that fans one prepared plan's batch across
/// [`crate::util::threadpool`] workers, keeping per-request semantics (and
/// outputs) identical to the wrapped backend.
///
/// Transparent to `prepare`/`execute`: `name()` forwards to the inner
/// backend, so plans prepared through the adapter pass the inner backend's
/// binding check and vice versa. Only `execute_batch` changes — the batch
/// is split into `workers` contiguous shards executed concurrently, and
/// degrades gracefully to the inner batch path for 1-element batches.
///
/// Sharding pays off when per-request execution is *serial*: the scalar
/// reference kernels, or CPU kernels below `blas::cpu`'s internal
/// parallelization threshold. Wrapping it around work that already fans
/// out per request (large-`n` `CpuBackend` routines) oversubscribes the
/// cores, and wrapping `SimBackend` is still wasteful: concurrent shards
/// race on its per-plan DES memo (so the DES may run once per shard rather
/// than once), and that DES already parallelizes internally across
/// dataflow components — prefer the inner backend directly in both cases.
pub struct ShardedBackend<B> {
    inner: B,
    workers: usize,
}

impl<B: Backend> ShardedBackend<B> {
    /// `workers` is clamped to at least 1.
    pub fn new(inner: B, workers: usize) -> ShardedBackend<B> {
        ShardedBackend { inner, workers: workers.max(1) }
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    pub fn workers(&self) -> usize {
        self.workers
    }
}

impl<B: Backend> Backend for ShardedBackend<B> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn prepare(&self, plan: Arc<ExecutablePlan>) -> Result<Prepared> {
        self.inner.prepare(plan)
    }

    fn execute(&self, prepared: &Prepared, inputs: &ExecInputs) -> Result<ExecOutcome> {
        self.inner.execute(prepared, inputs)
    }

    fn execute_batch(&self, prepared: &Prepared, batch: &[ExecInputs]) -> Vec<Result<ExecOutcome>> {
        let n = batch.len();
        if n <= 1 || self.workers == 1 {
            return self.inner.execute_batch(prepared, batch);
        }
        // one slot per contiguous chunk (each worker writes exactly one),
        // not one per request — shards.min(n) locks for the whole batch.
        let shards = self.workers.min(n);
        let slots: Vec<_> = (0..shards).map(|_| Mutex::new(None)).collect();
        crate::util::threadpool::parallel_chunks_with(n, shards, |i, start, end| {
            let outs = self.inner.execute_batch(prepared, &batch[start..end]);
            *slots[i].lock().expect("shard slot poisoned") = Some(outs);
        });
        let mut outcomes = Vec::with_capacity(n);
        for slot in slots {
            let outs =
                slot.into_inner().expect("shard slot poisoned").expect("shard worker panicked");
            outcomes.extend(outs);
        }
        if outcomes.len() != n {
            // a misbehaving inner backend dropped or invented outcomes;
            // surface the count mismatch rather than misassigning results.
            let msg = format!(
                "sharded inner backend {:?} returned {} outcome(s) for {} request(s)",
                self.inner.name(),
                outcomes.len(),
                n
            );
            return (0..n).map(|_| Err(Error::Runtime(msg.clone()))).collect();
        }
        outcomes
    }
}

// ---------------------------------------------------------------------------
// SlowBackend
// ---------------------------------------------------------------------------

/// Latency-injection adapter: delays every execute call by a fixed amount,
/// then delegates. Name-transparent (reports the inner backend's name) and
/// numerics-transparent, so substitution arguments about the wrapped
/// backend carry over unchanged.
///
/// This is the serving hardening suite's load generator: a deterministic
/// "slow device" that keeps dispatchers busy long enough for queues to
/// fill, deadlines to expire, quotas to bind and the adaptive pool to
/// react — without depending on scheduler timing of real work.
pub struct SlowBackend<B> {
    inner: B,
    delay: Duration,
}

impl<B: Backend> SlowBackend<B> {
    pub fn new(inner: B, delay: Duration) -> SlowBackend<B> {
        SlowBackend { inner, delay }
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }
}

impl<B: Backend> Backend for SlowBackend<B> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn prepare(&self, plan: Arc<ExecutablePlan>) -> Result<Prepared> {
        self.inner.prepare(plan)
    }

    fn execute(&self, prepared: &Prepared, inputs: &ExecInputs) -> Result<ExecOutcome> {
        std::thread::sleep(self.delay);
        self.inner.execute(prepared, inputs)
    }

    /// One delay per *batch* (not per request): the adapter models slow
    /// per-dispatch device setup, and keeping the batch path cheaper than
    /// n sequential executes preserves the incentive batching exists for.
    fn execute_batch(&self, prepared: &Prepared, batch: &[ExecInputs]) -> Vec<Result<ExecOutcome>> {
        std::thread::sleep(self.delay);
        self.inner.execute_batch(prepared, batch)
    }
}

// the serving layer holds backends behind Arc<dyn Backend> across threads.
const _: fn() = || {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<SimBackend<'static>>();
    assert_send_sync::<CpuBackend>();
    assert_send_sync::<ReferenceBackend>();
    assert_send_sync::<ShardedBackend<CpuBackend>>();
    assert_send_sync::<SlowBackend<CpuBackend>>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::PortType;
    use crate::spec::{DataSource, Spec};
    use crate::util::rng::Rng;

    fn plan(spec: &Spec) -> Arc<ExecutablePlan> {
        Arc::new(crate::pipeline::lower_spec(spec).unwrap())
    }

    #[test]
    fn reference_execute_axpy() {
        let out = ReferenceBackend::execute_named(
            "axpy",
            3,
            &[vec![2.0], vec![1.0, 2.0, 3.0], vec![10.0, 10.0, 10.0]],
        )
        .unwrap();
        assert_eq!(out, vec![12.0, 14.0, 16.0]);
    }

    #[test]
    fn reference_execute_axpy_neg_matches_paper_definition() {
        // z = w - alpha*v
        let out = ReferenceBackend::execute_named(
            "axpy_neg",
            2,
            &[vec![2.0], vec![1.0, 1.0], vec![5.0, 7.0]],
        )
        .unwrap();
        assert_eq!(out, vec![3.0, 5.0]);
    }

    #[test]
    fn reference_execute_wrong_arity_fails() {
        assert!(ReferenceBackend::execute_named("dot", 4, &[vec![0.0; 4]]).is_err());
        assert!(ReferenceBackend::execute_named("bogus", 4, &[]).is_err());
    }

    #[test]
    fn cpu_run_covers_all_kinds() {
        let mut rng = Rng::new(3);
        for kind in RoutineKind::ALL {
            let n = 64;
            let inputs: Vec<Vec<f32>> = kind
                .inputs()
                .iter()
                .map(|p| rng.normal_vec_f32(p.ty.elements(n)))
                .collect();
            let out = CpuBackend::run_kind(kind, n, &inputs);
            assert!(!out.is_empty(), "{kind}");
            assert!(out.iter().all(|v| v.is_finite()), "{kind}");
        }
    }

    #[test]
    fn backend_names_are_distinct() {
        let names = [
            SimBackend::timing_only().name(),
            CpuBackend.name(),
            ReferenceBackend.name(),
        ];
        assert_eq!(names, ["sim", "cpu", "reference"]);
    }

    #[test]
    fn sim_backend_times_without_inputs() {
        let spec = Spec::single(RoutineKind::Axpy, "a", 4096, DataSource::Pl);
        let backend = SimBackend::timing_only();
        let prepared = backend.prepare(plan(&spec)).unwrap();
        let outcome = backend.execute(&prepared, &ExecInputs::default()).unwrap();
        assert!(outcome.sim.expect("sim timing").makespan_s > 0.0);
        assert!(outcome.results.is_empty());
    }

    #[test]
    fn sim_backend_memoizes_des_per_plan() {
        let spec = Spec::axpydot_dataflow(4096, 2.0);
        let backend = SimBackend::timing_only();
        let prepared = backend.prepare(plan(&spec)).unwrap();
        let a = backend.execute(&prepared, &ExecInputs::default()).unwrap();
        {
            let memo = backend.sim_memo.lock().unwrap();
            let (memo_plan, _) = memo.as_ref().expect("first execute primes the memo");
            assert!(std::ptr::eq(memo_plan.as_ptr(), Arc::as_ptr(prepared.plan_arc())));
        }
        // repeats and batches serve the memoized report bit-identically.
        let a_makespan = a.sim.expect("sim timing").makespan_s;
        let b = backend.execute(&prepared, &ExecInputs::default()).unwrap();
        assert_eq!(a_makespan, b.sim.expect("sim timing").makespan_s);
        let batch =
            backend.execute_batch(&prepared, &[ExecInputs::default(), ExecInputs::default()]);
        assert_eq!(batch.len(), 2);
        for out in batch {
            assert_eq!(a_makespan, out.unwrap().sim.expect("sim timing").makespan_s);
        }
        // a different plan takes over the one-deep memo.
        let other = backend
            .prepare(plan(&Spec::single(RoutineKind::Axpy, "a", 2048, DataSource::Pl)))
            .unwrap();
        backend.execute(&other, &ExecInputs::default()).unwrap();
        let memo = backend.sim_memo.lock().unwrap();
        let (memo_plan, _) = memo.as_ref().expect("memo follows the latest plan");
        assert!(std::ptr::eq(memo_plan.as_ptr(), Arc::as_ptr(other.plan_arc())));
    }

    #[test]
    fn cpu_and_reference_agree_via_trait() {
        let spec = Spec::single(RoutineKind::Axpy, "a", 1024, DataSource::Pl);
        let p = plan(&spec);
        let inputs = ExecInputs::random_for(&spec, 11);
        let cpu = CpuBackend.execute(&CpuBackend.prepare(p.clone()).unwrap(), &inputs).unwrap();
        let reference = ReferenceBackend
            .execute(&ReferenceBackend.prepare(p).unwrap(), &inputs)
            .unwrap();
        assert_eq!(cpu.results[0].provenance, Provenance::Cpu);
        assert_eq!(reference.results[0].provenance, Provenance::Reference);
        for (a, b) in cpu.results[0].output.iter().zip(&reference.results[0].output) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn prepared_plan_is_backend_checked() {
        let spec = Spec::single(RoutineKind::Dot, "d", 256, DataSource::Pl);
        let prepared = CpuBackend.prepare(plan(&spec)).unwrap();
        let err = ReferenceBackend.execute(&prepared, &ExecInputs::random_for(&spec, 1));
        assert!(matches!(err, Err(Error::Runtime(_))));
    }

    #[test]
    fn missing_inputs_error_cleanly() {
        let spec = Spec::single(RoutineKind::Dot, "d", 256, DataSource::Pl);
        let prepared = CpuBackend.prepare(plan(&spec)).unwrap();
        assert!(CpuBackend.execute(&prepared, &ExecInputs::default()).is_err());
    }

    #[test]
    fn slow_backend_is_name_and_numerics_transparent() {
        let spec = Spec::single(RoutineKind::Axpy, "a", 256, DataSource::Pl);
        let p = plan(&spec);
        let inputs = ExecInputs::random_for(&spec, 7);
        let slow = SlowBackend::new(CpuBackend, Duration::from_millis(1));
        assert_eq!(slow.name(), CpuBackend.name());
        let t0 = Instant::now();
        let out = slow.execute(&slow.prepare(p.clone()).unwrap(), &inputs).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(1), "delay must be injected");
        let direct = CpuBackend.execute(&CpuBackend.prepare(p).unwrap(), &inputs).unwrap();
        assert_eq!(out.results[0].output, direct.results[0].output, "bit-identical delegation");
    }

    #[test]
    fn exec_inputs_match_port_shapes() {
        let spec = Spec::axpydot_dataflow(512, 2.0);
        let inputs = ExecInputs::random_for(&spec, 5);
        assert_eq!(inputs.per_routine.len(), 2);
        for (r, rin) in spec.routines.iter().zip(&inputs.per_routine) {
            assert_eq!(rin.len(), r.kind.inputs().len());
            for (p, v) in r.kind.inputs().iter().zip(rin) {
                assert_eq!(v.len(), p.ty.elements(r.size));
                if p.ty == PortType::Scalar {
                    assert_eq!(v.len(), 1);
                }
            }
        }
    }
}
