//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! Rust — the request path never touches Python.
//!
//! Follows the reference wiring in `/opt/xla-example/load_hlo`: HLO *text*
//! (not serialized protos — jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects) is parsed by `HloModuleProto::from_text_file`,
//! compiled once per (routine, size) on the PJRT CPU client and cached.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

pub use manifest::Manifest;

use crate::blas::RoutineKind;
use crate::{Error, Result};

/// Executes precompiled BLAS artifacts via PJRT, with a reference-Rust
/// fallback for shapes that were not precompiled.
pub struct NumericExecutor {
    manifest: Manifest,
    client: Option<xla::PjRtClient>,
    /// key → compiled executable (compile once, execute many).
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Executions served by PJRT vs the fallback (observability).
    pub pjrt_calls: RefCell<u64>,
    pub fallback_calls: RefCell<u64>,
}

/// Where a result came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Pjrt,
    ReferenceFallback,
}

impl NumericExecutor {
    /// Create an executor over `artifacts_dir`. The PJRT client is created
    /// lazily-but-once here; failure to initialise it (or an empty
    /// manifest) degrades to the reference fallback rather than erroring,
    /// so the system works before `make artifacts`.
    pub fn new(artifacts_dir: &Path) -> Result<NumericExecutor> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = if manifest.is_empty() {
            None
        } else {
            match xla::PjRtClient::cpu() {
                Ok(c) => Some(c),
                Err(e) => {
                    log::warn!("PJRT CPU client unavailable ({e}); using reference fallback");
                    None
                }
            }
        };
        Ok(NumericExecutor {
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
            pjrt_calls: RefCell::new(0),
            fallback_calls: RefCell::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// True when a PJRT artifact will serve this (routine, size).
    pub fn has_artifact(&self, routine: &str, size: usize) -> bool {
        self.client.is_some() && self.manifest.find(routine, size).is_some()
    }

    /// Execute routine `name` at problem size `size` with flat f32 inputs
    /// (in manifest parameter order). Returns (output, backend).
    pub fn execute(
        &self,
        name: &str,
        size: usize,
        inputs: &[Vec<f32>],
    ) -> Result<(Vec<f32>, Backend)> {
        validate_inputs(name, size, inputs)?;
        if self.has_artifact(name, size) {
            match self.execute_pjrt(name, size, inputs) {
                Ok(out) => {
                    *self.pjrt_calls.borrow_mut() += 1;
                    return Ok((out, Backend::Pjrt));
                }
                Err(e) => {
                    log::warn!("PJRT execution of {name}_n{size} failed ({e}); falling back");
                }
            }
        }
        let out = reference_execute(name, size, inputs)?;
        *self.fallback_calls.borrow_mut() += 1;
        Ok((out, Backend::ReferenceFallback))
    }

    fn execute_pjrt(&self, name: &str, size: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(name, size)
            .ok_or_else(|| Error::Runtime(format!("no artifact for {name}_n{size}")))?;
        let client = self
            .client
            .as_ref()
            .ok_or_else(|| Error::Runtime("no PJRT client".into()))?;

        if inputs.len() != entry.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: got {} inputs, artifact wants {}",
                entry.key,
                inputs.len(),
                entry.inputs.len()
            )));
        }

        // compile (cached)
        if !self.cache.borrow().contains_key(&entry.key) {
            let path = entry.file.to_str().ok_or_else(|| {
                Error::Runtime(format!("non-utf8 artifact path {:?}", entry.file))
            })?;
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            self.cache.borrow_mut().insert(entry.key.clone(), exe);
        }

        // literals in parameter order
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, sig) in inputs.iter().zip(&entry.inputs) {
            let expected: usize = sig.shape.iter().product::<usize>().max(1);
            if data.len() != expected {
                return Err(Error::Runtime(format!(
                    "{}: input length {} != shape {:?}",
                    entry.key,
                    data.len(),
                    sig.shape
                )));
            }
            let lit = xla::Literal::vec1(data);
            let lit = if sig.shape.len() > 1 {
                let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)?
            } else {
                lit
            };
            literals.push(lit);
        }

        let cache = self.cache.borrow();
        let exe = cache.get(&entry.key).expect("just inserted");
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // lowered with return_tuple=True → flatten ALL tuple leaves in
        // order (single-output routines are 1-tuples; rot is a 2-tuple).
        let leaves = result.to_tuple()?;
        let mut flat = Vec::new();
        for leaf in leaves {
            // most routines emit f32; iamax emits an int32 index.
            match leaf.to_vec::<f32>() {
                Ok(v) => flat.extend(v),
                Err(_) => flat.extend(leaf.to_vec::<i32>()?.into_iter().map(|v| v as f32)),
            }
        }
        Ok(flat)
    }
}

/// Validate input arity and lengths against the routine's port signature
/// *before* dispatching to either backend — malformed requests must error,
/// not fall back or panic.
pub fn validate_inputs(name: &str, size: usize, inputs: &[Vec<f32>]) -> Result<()> {
    let base = if name == "axpy_neg" { "axpy" } else { name };
    let kind = RoutineKind::from_name(base)
        .ok_or_else(|| Error::Runtime(format!("unknown routine {name:?}")))?;
    let ports = kind.inputs();
    if inputs.len() != ports.len() {
        return Err(Error::Runtime(format!(
            "{name}: expected {} inputs, got {}",
            ports.len(),
            inputs.len()
        )));
    }
    for (i, (data, port)) in inputs.iter().zip(ports).enumerate() {
        let want = port.ty.elements(size);
        if data.len() != want {
            return Err(Error::Runtime(format!(
                "{name}: input {i} ({}) has {} elements, expected {want}",
                port.name,
                data.len()
            )));
        }
    }
    Ok(())
}

/// Reference-Rust execution of a routine given flat inputs in artifact
/// parameter order (the same order `RoutineKind::inputs()` declares).
pub fn reference_execute(name: &str, size: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
    use crate::blas::reference as r;
    let n = size;
    let need = |k: usize| -> Result<()> {
        if inputs.len() != k {
            return Err(Error::Runtime(format!("{name}: expected {k} inputs, got {}", inputs.len())));
        }
        Ok(())
    };
    let kind = RoutineKind::from_name(name.strip_suffix("_neg").unwrap_or(name))
        .or(match name {
            "axpy_neg" => Some(RoutineKind::Axpy),
            _ => None,
        })
        .ok_or_else(|| Error::Runtime(format!("unknown routine {name:?}")))?;
    match (name, kind) {
        ("axpy", _) => {
            need(3)?;
            let mut z = vec![0.0; n];
            r::axpy(inputs[0][0], &inputs[1], &inputs[2], &mut z);
            Ok(z)
        }
        ("axpy_neg", _) => {
            // z = w - alpha*v with params (alpha, v, w)
            need(3)?;
            let mut z = vec![0.0; n];
            r::axpy(-inputs[0][0], &inputs[1], &inputs[2], &mut z);
            Ok(z)
        }
        (_, RoutineKind::Axpby) => {
            need(4)?;
            let mut z = vec![0.0; n];
            r::axpby(inputs[0][0], &inputs[2], inputs[1][0], &inputs[3], &mut z);
            Ok(z)
        }
        (_, RoutineKind::Rot) => {
            // concatenated outputs (x_out ++ y_out), matching the PJRT
            // tuple flattening.
            need(4)?;
            let mut xo = vec![0.0; n];
            let mut yo = vec![0.0; n];
            r::rot(inputs[0][0], inputs[1][0], &inputs[2], &inputs[3], &mut xo, &mut yo);
            xo.extend(yo);
            Ok(xo)
        }
        (_, RoutineKind::Ger) => {
            need(4)?;
            let mut out = vec![0.0; n * n];
            r::ger(inputs[0][0], &inputs[1], &inputs[2], &inputs[3], n, n, &mut out);
            Ok(out)
        }
        (_, RoutineKind::Scal) => {
            need(2)?;
            let mut z = vec![0.0; n];
            r::scal(inputs[0][0], &inputs[1], &mut z);
            Ok(z)
        }
        (_, RoutineKind::Copy) => {
            need(1)?;
            Ok(inputs[0].clone())
        }
        (_, RoutineKind::Dot) => {
            need(2)?;
            Ok(vec![r::dot(&inputs[0], &inputs[1])])
        }
        (_, RoutineKind::Nrm2) => {
            need(1)?;
            Ok(vec![r::nrm2(&inputs[0])])
        }
        (_, RoutineKind::Asum) => {
            need(1)?;
            Ok(vec![r::asum(&inputs[0])])
        }
        (_, RoutineKind::Iamax) => {
            need(1)?;
            Ok(vec![r::iamax(&inputs[0]) as f32])
        }
        (_, RoutineKind::Gemv) => {
            need(5)?;
            let mut out = vec![0.0; n];
            r::gemv(inputs[0][0], &inputs[1], n, n, &inputs[2], inputs[3][0], &inputs[4], &mut out);
            Ok(out)
        }
        (_, RoutineKind::Gemm) => {
            need(5)?;
            let mut out = vec![0.0; n * n];
            r::gemm(inputs[0][0], &inputs[1], &inputs[2], n, n, n, inputs[3][0], &inputs[4], &mut out);
            Ok(out)
        }
        (_, RoutineKind::Axpydot) => {
            need(4)?;
            Ok(vec![r::axpydot(inputs[0][0], &inputs[1], &inputs[2], &inputs[3])])
        }
        _ => Err(Error::Runtime(format!("unhandled routine {name:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn reference_execute_axpy() {
        let out = reference_execute(
            "axpy",
            3,
            &[vec![2.0], vec![1.0, 2.0, 3.0], vec![10.0, 10.0, 10.0]],
        )
        .unwrap();
        assert_eq!(out, vec![12.0, 14.0, 16.0]);
    }

    #[test]
    fn reference_execute_axpy_neg_matches_paper_definition() {
        // z = w - alpha*v
        let out =
            reference_execute("axpy_neg", 2, &[vec![2.0], vec![1.0, 1.0], vec![5.0, 7.0]]).unwrap();
        assert_eq!(out, vec![3.0, 5.0]);
    }

    #[test]
    fn reference_execute_wrong_arity_fails() {
        assert!(reference_execute("dot", 4, &[vec![0.0; 4]]).is_err());
        assert!(reference_execute("bogus", 4, &[]).is_err());
    }

    #[test]
    fn executor_without_artifacts_falls_back() {
        let ex = NumericExecutor::new(Path::new("/nonexistent_dir_xyz")).unwrap();
        let (out, backend) = ex
            .execute("dot", 4, &[vec![1.0, 2.0, 3.0, 4.0], vec![1.0, 1.0, 1.0, 1.0]])
            .unwrap();
        assert_eq!(backend, Backend::ReferenceFallback);
        assert_eq!(out, vec![10.0]);
    }

    /// The cross-language correctness loop: PJRT artifact (Pallas-lowered
    /// HLO) vs the Rust reference, on every precompiled routine. Skips
    /// silently when `make artifacts` has not run.
    #[test]
    fn pjrt_matches_reference_for_all_artifacts() {
        let ex = NumericExecutor::new(&artifacts_dir()).unwrap();
        if ex.manifest().is_empty() {
            eprintln!("skipping: no artifacts built");
            return;
        }
        let mut rng = Rng::new(42);
        let mut checked = 0;
        for entry in ex.manifest().entries() {
            if entry.size > 1 << 16 {
                continue; // keep the test fast
            }
            let inputs: Vec<Vec<f32>> = entry
                .inputs
                .iter()
                .map(|sig| {
                    let len: usize = sig.shape.iter().product::<usize>().max(1);
                    rng.normal_vec_f32(len)
                })
                .collect();
            let (pjrt_out, backend) = ex.execute(&entry.routine, entry.size, &inputs).unwrap();
            assert_eq!(backend, Backend::Pjrt, "{}", entry.key);
            let ref_out = reference_execute(&entry.routine, entry.size, &inputs).unwrap();
            assert_eq!(pjrt_out.len(), ref_out.len(), "{}", entry.key);
            if entry.routine == "iamax" {
                // index equality
                assert_eq!(pjrt_out[0] as usize, ref_out[0] as usize, "{}", entry.key);
            } else {
                for (i, (a, b)) in pjrt_out.iter().zip(&ref_out).enumerate() {
                    let tol = 2e-3 * (1.0 + b.abs());
                    assert!(
                        (a - b).abs() <= tol,
                        "{}[{i}]: pjrt {a} vs ref {b}",
                        entry.key
                    );
                }
            }
            checked += 1;
        }
        assert!(checked > 10, "only {checked} artifacts checked");
        assert_eq!(*ex.fallback_calls.borrow(), 0);
    }

    #[test]
    fn pjrt_compile_cache_is_reused() {
        let ex = NumericExecutor::new(&artifacts_dir()).unwrap();
        if !ex.has_artifact("axpy", 4096) {
            eprintln!("skipping: no artifacts built");
            return;
        }
        let mut rng = Rng::new(1);
        let inputs = vec![vec![1.5], rng.normal_vec_f32(4096), rng.normal_vec_f32(4096)];
        ex.execute("axpy", 4096, &inputs).unwrap();
        ex.execute("axpy", 4096, &inputs).unwrap();
        assert_eq!(ex.cache.borrow().len(), 1);
        assert_eq!(*ex.pjrt_calls.borrow(), 2);
    }
}
