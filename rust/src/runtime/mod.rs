//! Execution runtime: the [`Backend`] trait with its three in-crate
//! implementations, plus the PJRT artifact executor.
//!
//! The PJRT path (feature `pjrt`) follows the reference wiring in
//! `/opt/xla-example/load_hlo`: HLO *text* (not serialized protos — jax ≥
//! 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects) is
//! parsed by `HloModuleProto::from_text_file`, compiled once per (routine,
//! size) on the PJRT CPU client and cached. The default build has no
//! external dependencies: `NumericExecutor` then always serves requests
//! from [`ReferenceBackend`], so the whole system works without `make
//! artifacts` or the vendored `xla` crate closure (DESIGN.md §1).

pub mod backend;
pub mod manifest;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

pub use backend::{
    Backend, CpuBackend, ExecInputs, ExecOutcome, Prepared, ReferenceBackend, RoutineResult,
    ShardedBackend, SimBackend, SlowBackend,
};
pub use manifest::Manifest;

use crate::blas::RoutineKind;
use crate::{Error, Result};

/// Where a numeric result came from (per-routine observability; the
/// coarse-grained execution target is the [`Backend`] trait).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provenance {
    /// A precompiled PJRT artifact (feature `pjrt`).
    Pjrt,
    /// The scalar reference implementation ([`ReferenceBackend`]).
    Reference,
    /// The threaded CPU BLAS ([`CpuBackend`]).
    Cpu,
}

/// Executes precompiled BLAS artifacts via PJRT, with the reference
/// backend serving shapes that were not precompiled (or every request
/// when the `pjrt` feature is disabled).
///
/// `Sync` by construction (atomic counters, mutex'd compile cache) so the
/// serving layer can share one executor across backend-pool threads. With
/// the `pjrt` feature the `Sync` bound additionally rides on the vendored
/// `xla` types being shareable; the compile cache's mutex already
/// serializes access to them.
pub struct NumericExecutor {
    manifest: Manifest,
    #[cfg(feature = "pjrt")]
    client: Option<xla::PjRtClient>,
    /// key → compiled executable (compile once, execute many).
    #[cfg(feature = "pjrt")]
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Executions served by PJRT vs the fallback (observability).
    pjrt_calls: AtomicU64,
    fallback_calls: AtomicU64,
}

impl NumericExecutor {
    /// Create an executor over `artifacts_dir`. With the `pjrt` feature the
    /// client is created lazily-but-once here; failure to initialise it (or
    /// an empty manifest) degrades to the reference backend rather than
    /// erroring, so the system works before `make artifacts`.
    pub fn new(artifacts_dir: &Path) -> Result<NumericExecutor> {
        let manifest = Manifest::load(artifacts_dir)?;
        #[cfg(feature = "pjrt")]
        let client = if manifest.is_empty() {
            None
        } else {
            match xla::PjRtClient::cpu() {
                Ok(c) => Some(c),
                Err(e) => {
                    crate::log_warn!("PJRT CPU client unavailable ({e}); using reference backend");
                    None
                }
            }
        };
        #[cfg(not(feature = "pjrt"))]
        if !manifest.is_empty() {
            crate::log_warn!(
                "artifacts present but the `pjrt` feature is disabled; numerics use the reference backend"
            );
        }
        Ok(NumericExecutor {
            manifest,
            #[cfg(feature = "pjrt")]
            client,
            #[cfg(feature = "pjrt")]
            cache: Mutex::new(HashMap::new()),
            pjrt_calls: AtomicU64::new(0),
            fallback_calls: AtomicU64::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Executions served by a PJRT artifact.
    pub fn pjrt_calls(&self) -> u64 {
        self.pjrt_calls.load(Ordering::Relaxed)
    }

    /// Executions served by the reference fallback.
    pub fn fallback_calls(&self) -> u64 {
        self.fallback_calls.load(Ordering::Relaxed)
    }

    /// True when a PJRT artifact will serve this (routine, size).
    #[cfg(feature = "pjrt")]
    pub fn has_artifact(&self, routine: &str, size: usize) -> bool {
        self.client.is_some() && self.manifest.find(routine, size).is_some()
    }

    /// Without the `pjrt` feature no artifact is ever served.
    #[cfg(not(feature = "pjrt"))]
    pub fn has_artifact(&self, _routine: &str, _size: usize) -> bool {
        false
    }

    /// Execute routine `name` at problem size `size` with flat f32 inputs
    /// (in manifest parameter order). Returns (output, provenance).
    pub fn execute(
        &self,
        name: &str,
        size: usize,
        inputs: &[Vec<f32>],
    ) -> Result<(Vec<f32>, Provenance)> {
        validate_inputs(name, size, inputs)?;
        #[cfg(feature = "pjrt")]
        if self.has_artifact(name, size) {
            match self.execute_pjrt(name, size, inputs) {
                Ok(out) => {
                    self.pjrt_calls.fetch_add(1, Ordering::Relaxed);
                    return Ok((out, Provenance::Pjrt));
                }
                Err(e) => {
                    crate::log_warn!("PJRT execution of {name}_n{size} failed ({e}); falling back");
                }
            }
        }
        let out = ReferenceBackend::execute_named(name, size, inputs)?;
        self.fallback_calls.fetch_add(1, Ordering::Relaxed);
        Ok((out, Provenance::Reference))
    }

    #[cfg(feature = "pjrt")]
    fn execute_pjrt(&self, name: &str, size: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(name, size)
            .ok_or_else(|| Error::Runtime(format!("no artifact for {name}_n{size}")))?;
        let client = self
            .client
            .as_ref()
            .ok_or_else(|| Error::Runtime("no PJRT client".into()))?;

        if inputs.len() != entry.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: got {} inputs, artifact wants {}",
                entry.key,
                inputs.len(),
                entry.inputs.len()
            )));
        }

        // compile (cached); the lock also serializes PJRT execution below
        let mut cache = self.cache.lock().expect("pjrt compile cache poisoned");
        if !cache.contains_key(&entry.key) {
            let path = entry.file.to_str().ok_or_else(|| {
                Error::Runtime(format!("non-utf8 artifact path {:?}", entry.file))
            })?;
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            cache.insert(entry.key.clone(), exe);
        }

        // literals in parameter order
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, sig) in inputs.iter().zip(&entry.inputs) {
            let expected: usize = sig.shape.iter().product::<usize>().max(1);
            if data.len() != expected {
                return Err(Error::Runtime(format!(
                    "{}: input length {} != shape {:?}",
                    entry.key,
                    data.len(),
                    sig.shape
                )));
            }
            let lit = xla::Literal::vec1(data);
            let lit = if sig.shape.len() > 1 {
                let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)?
            } else {
                lit
            };
            literals.push(lit);
        }

        let exe = cache.get(&entry.key).expect("just inserted");
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // lowered with return_tuple=True → flatten ALL tuple leaves in
        // order (single-output routines are 1-tuples; rot is a 2-tuple).
        let leaves = result.to_tuple()?;
        let mut flat = Vec::new();
        for leaf in leaves {
            // most routines emit f32; iamax emits an int32 index.
            match leaf.to_vec::<f32>() {
                Ok(v) => flat.extend(v),
                Err(_) => flat.extend(leaf.to_vec::<i32>()?.into_iter().map(|v| v as f32)),
            }
        }
        Ok(flat)
    }
}

/// Validate input arity and lengths against the routine's port signature
/// *before* dispatching to any backend — malformed requests must error,
/// not fall back or panic.
pub fn validate_inputs(name: &str, size: usize, inputs: &[Vec<f32>]) -> Result<()> {
    let base = if name == "axpy_neg" { "axpy" } else { name };
    let kind = RoutineKind::from_name(base)
        .ok_or_else(|| Error::Runtime(format!("unknown routine {name:?}")))?;
    let ports = kind.inputs();
    if inputs.len() != ports.len() {
        return Err(Error::Runtime(format!(
            "{name}: expected {} inputs, got {}",
            ports.len(),
            inputs.len()
        )));
    }
    for (i, (data, port)) in inputs.iter().zip(ports).enumerate() {
        let want = port.ty.elements(size);
        if data.len() != want {
            return Err(Error::Runtime(format!(
                "{name}: input {i} ({}) has {} elements, expected {want}",
                port.name,
                data.len()
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn executor_without_artifacts_falls_back() {
        let ex = NumericExecutor::new(Path::new("/nonexistent_dir_xyz")).unwrap();
        let (out, provenance) = ex
            .execute("dot", 4, &[vec![1.0, 2.0, 3.0, 4.0], vec![1.0, 1.0, 1.0, 1.0]])
            .unwrap();
        assert_eq!(provenance, Provenance::Reference);
        assert_eq!(out, vec![10.0]);
        assert_eq!(ex.fallback_calls(), 1);
    }

    #[test]
    fn malformed_request_is_error_not_fallback() {
        let ex = NumericExecutor::new(Path::new("/nonexistent_dir_xyz")).unwrap();
        assert!(ex.execute("dot", 4, &[vec![0.0; 4]]).is_err());
        assert!(ex.execute("bogus", 4, &[]).is_err());
        assert_eq!(ex.fallback_calls(), 0);
    }

    /// The cross-language correctness loop: PJRT artifact (Pallas-lowered
    /// HLO) vs the Rust reference, on every precompiled routine. Skips
    /// silently when `make artifacts` has not run or `pjrt` is disabled.
    #[test]
    fn pjrt_matches_reference_for_all_artifacts() {
        if cfg!(not(feature = "pjrt")) {
            eprintln!("skipping: pjrt feature disabled");
            return;
        }
        let ex = NumericExecutor::new(&artifacts_dir()).unwrap();
        if ex.manifest().is_empty() {
            eprintln!("skipping: no artifacts built");
            return;
        }
        let mut rng = Rng::new(42);
        let mut checked = 0;
        for entry in ex.manifest().entries() {
            if entry.size > 1 << 16 {
                continue; // keep the test fast
            }
            let inputs: Vec<Vec<f32>> = entry
                .inputs
                .iter()
                .map(|sig| {
                    let len: usize = sig.shape.iter().product::<usize>().max(1);
                    rng.normal_vec_f32(len)
                })
                .collect();
            let (pjrt_out, provenance) = ex.execute(&entry.routine, entry.size, &inputs).unwrap();
            assert_eq!(provenance, Provenance::Pjrt, "{}", entry.key);
            let ref_out =
                ReferenceBackend::execute_named(&entry.routine, entry.size, &inputs).unwrap();
            assert_eq!(pjrt_out.len(), ref_out.len(), "{}", entry.key);
            if entry.routine == "iamax" {
                // index equality
                assert_eq!(pjrt_out[0] as usize, ref_out[0] as usize, "{}", entry.key);
            } else {
                for (i, (a, b)) in pjrt_out.iter().zip(&ref_out).enumerate() {
                    let tol = 2e-3 * (1.0 + b.abs());
                    assert!((a - b).abs() <= tol, "{}[{i}]: pjrt {a} vs ref {b}", entry.key);
                }
            }
            checked += 1;
        }
        assert!(checked > 10, "only {checked} artifacts checked");
        assert_eq!(ex.fallback_calls(), 0);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_compile_cache_is_reused() {
        let ex = NumericExecutor::new(&artifacts_dir()).unwrap();
        if !ex.has_artifact("axpy", 4096) {
            eprintln!("skipping: no artifacts built");
            return;
        }
        let mut rng = Rng::new(1);
        let inputs = vec![vec![1.5], rng.normal_vec_f32(4096), rng.normal_vec_f32(4096)];
        ex.execute("axpy", 4096, &inputs).unwrap();
        ex.execute("axpy", 4096, &inputs).unwrap();
        assert_eq!(ex.cache.lock().unwrap().len(), 1);
        assert_eq!(ex.pjrt_calls(), 2);
    }
}
