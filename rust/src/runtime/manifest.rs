//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes it at build time) and the Rust runtime (which loads HLO text by
//! key at run time). Python never runs on the request path.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

/// Input signature of one artifact parameter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One precompiled (routine, size) artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    pub key: String,
    pub routine: String,
    pub size: usize,
    pub file: PathBuf,
    pub inputs: Vec<InputSig>,
    pub num_outputs: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    entries: BTreeMap<String, Entry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`. A missing manifest yields an *empty*
    /// manifest (the runtime then falls back to the in-crate reference
    /// implementations, keeping `cargo test` independent of `make
    /// artifacts`).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Ok(Manifest { dir: dir.to_path_buf(), entries: BTreeMap::new() });
        }
        let text = std::fs::read_to_string(&path)?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON text.
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let json = Json::parse(text)?;
        let entries_json = json
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Runtime("manifest has no entries array".into()))?;
        if json.get("interchange").and_then(Json::as_str) != Some("hlo-text") {
            return Err(Error::Runtime(
                "manifest interchange is not hlo-text (regenerate artifacts)".into(),
            ));
        }
        let mut entries = BTreeMap::new();
        for e in entries_json {
            let key = e
                .get("key")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Runtime("entry missing key".into()))?
                .to_string();
            let routine = e
                .get("routine")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Runtime(format!("{key}: missing routine")))?
                .to_string();
            let size = e
                .get("size")
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Runtime(format!("{key}: missing size")))?;
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Runtime(format!("{key}: missing file")))?;
            let mut inputs = Vec::new();
            for i in e.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
                let shape = i
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                let dtype = i
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("float32")
                    .to_string();
                inputs.push(InputSig { shape, dtype });
            }
            let num_outputs = e.get("num_outputs").and_then(Json::as_usize).unwrap_or(1);
            entries.insert(
                key.clone(),
                Entry { key, routine, size, file: dir.join(file), inputs, num_outputs },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Exact (routine, size) lookup.
    pub fn find(&self, routine: &str, size: usize) -> Option<&Entry> {
        self.entries.get(&format!("{routine}_n{size}"))
    }

    /// All sizes precompiled for a routine (ascending).
    pub fn sizes_for(&self, routine: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .values()
            .filter(|e| e.routine == routine)
            .map(|e| e.size)
            .collect();
        v.sort_unstable();
        v
    }

    pub fn entries(&self) -> impl Iterator<Item = &Entry> {
        self.entries.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "interchange": "hlo-text",
      "entries": [
        {"key": "axpy_n4096", "routine": "axpy", "size": 4096,
         "file": "axpy_n4096.hlo.txt",
         "inputs": [{"shape": [1], "dtype": "float32"},
                     {"shape": [4096], "dtype": "float32"},
                     {"shape": [4096], "dtype": "float32"}],
         "num_outputs": 1},
        {"key": "axpy_n65536", "routine": "axpy", "size": 65536,
         "file": "axpy_n65536.hlo.txt", "inputs": [], "num_outputs": 1}
      ]
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let e = m.find("axpy", 4096).unwrap();
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[1].shape, vec![4096]);
        assert_eq!(e.file, Path::new("/tmp/a/axpy_n4096.hlo.txt"));
        assert_eq!(m.sizes_for("axpy"), vec![4096, 65536]);
        assert!(m.find("axpy", 999).is_none());
    }

    #[test]
    fn missing_manifest_is_empty() {
        let m = Manifest::load(Path::new("/nonexistent_dir_xyz")).unwrap();
        assert!(m.is_empty());
    }

    #[test]
    fn wrong_interchange_rejected() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn real_manifest_parses_when_built() {
        // integration hook: when `make artifacts` has run, exercise the
        // real manifest too.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let m = Manifest::load(&dir).unwrap();
        if !m.is_empty() {
            assert!(m.find("axpy", 65536).is_some());
            assert!(m.find("axpydot", 65536).is_some());
        }
    }
}
