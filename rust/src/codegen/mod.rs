//! Template-based code generation (paper §III, Fig. 1 ①–④).
//!
//! From the validated spec AIEBLAS generates the complete Vitis design a
//! user would compile for a real VCK5000:
//!
//! 1. **AIE kernels** (`aie/<name>.cc/.h`) — vectorized ADF C++ using the
//!    window/stream APIs ([`aie_kernel`]);
//! 2. **PL kernels** (`pl/mm2s.cpp`, `pl/s2mm.cpp`) — HLS data movers
//!    ([`pl_kernel`]);
//! 3. **dataflow graph** (`aie/graph.h`, `aie/graph.cpp`) — the ADF graph
//!    connecting kernels and movers ([`adf_graph`]);
//! 4. **build project** (`CMakeLists.txt`, `system.cfg`, `host.cpp`)
//!    ([`project`]).
//!
//! Since no Vitis toolchain exists in this environment, the generated
//! sources are validated structurally (golden tests, determinism,
//! C-identifier hygiene) and the *behaviour* of the generated design is
//! what the simulator executes; the generated text matches the AIEBLAS
//! repository's layout so it would drop into a real Vitis flow.

pub mod adf_graph;
pub mod aie_kernel;
pub mod pl_kernel;
pub mod project;

use std::collections::BTreeMap;
use std::path::Path;

use crate::spec::Spec;
use crate::Result;

/// A generated source tree: path → file contents. BTreeMap for
/// deterministic iteration (stable golden tests).
#[derive(Debug, Clone, Default)]
pub struct GeneratedProject {
    pub files: BTreeMap<String, String>,
}

impl GeneratedProject {
    pub fn insert(&mut self, path: impl Into<String>, contents: String) {
        self.files.insert(path.into(), contents);
    }

    pub fn get(&self, path: &str) -> Option<&str> {
        self.files.get(path).map(String::as_str)
    }

    /// Total generated lines (reported by the CLI).
    pub fn total_lines(&self) -> usize {
        self.files.values().map(|c| c.lines().count()).sum()
    }

    /// Write all files under `root`, creating directories as needed.
    pub fn write_to(&self, root: &Path) -> Result<()> {
        for (rel, contents) in &self.files {
            let path = root.join(rel);
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(path, contents)?;
        }
        Ok(())
    }
}

/// Generate the complete project for a validated spec (one-shot: validates
/// and builds the graph itself; the staged pipeline calls
/// [`generate_from_built`] with the graph it already has).
pub fn generate(spec: &Spec) -> Result<GeneratedProject> {
    crate::spec::validate(spec)?;
    let built = crate::graph::build::build_graph(spec)?;
    generate_from_built(spec, &built)
}

/// Generate the project from an already-built dataflow graph (pipeline
/// stage 1; avoids re-validating and re-building).
pub fn generate_from_built(
    spec: &Spec,
    built: &crate::graph::build::BuildOutput,
) -> Result<GeneratedProject> {
    let mut proj = GeneratedProject::default();

    // 1. AIE kernels
    for node in &built.graph.nodes {
        if let crate::graph::NodeKind::AieKernel { kind, size, window, vector_bits, .. } =
            &node.kind
        {
            let header = aie_kernel::kernel_header(&node.name, *kind);
            let source = aie_kernel::kernel_source(
                &node.name,
                *kind,
                *size,
                *window,
                *vector_bits,
                spec,
            );
            proj.insert(format!("aie/kernels/{}.h", node.name), header);
            proj.insert(format!("aie/kernels/{}.cc", node.name), source);
        }
    }

    // 2. PL movers (one shared implementation each, instantiated per port
    //    in the connectivity config)
    let any_burst = spec.routines.iter().any(|r| r.burst);
    if built.graph.num_pl_movers() > 0 {
        proj.insert("pl/mm2s.cpp".to_string(), pl_kernel::mm2s_source(any_burst));
        proj.insert("pl/s2mm.cpp".to_string(), pl_kernel::s2mm_source(any_burst));
    }

    // 3. dataflow graph
    proj.insert("aie/graph.h".to_string(), adf_graph::graph_header(spec, built)?);
    proj.insert("aie/graph.cpp".to_string(), adf_graph::graph_source(spec));

    // 4. build project
    proj.insert("CMakeLists.txt".to_string(), project::cmake(spec, built));
    proj.insert("system.cfg".to_string(), project::connectivity(spec, built));
    proj.insert("host/host.cpp".to_string(), project::host(spec, built));
    proj.insert("README.md".to_string(), project::readme(spec));

    Ok(proj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::spec::{DataSource, Spec};

    #[test]
    fn generates_expected_file_set_for_axpy() {
        let spec = Spec::single(RoutineKind::Axpy, "vadd", 4096, DataSource::Pl);
        let p = generate(&spec).unwrap();
        for f in [
            "aie/kernels/vadd.h",
            "aie/kernels/vadd.cc",
            "pl/mm2s.cpp",
            "pl/s2mm.cpp",
            "aie/graph.h",
            "aie/graph.cpp",
            "CMakeLists.txt",
            "system.cfg",
            "host/host.cpp",
            "README.md",
        ] {
            assert!(p.get(f).is_some(), "missing {f}");
        }
    }

    #[test]
    fn onchip_design_has_no_pl_kernels() {
        let spec = Spec::single(RoutineKind::Axpy, "vadd", 4096, DataSource::OnChip);
        let p = generate(&spec).unwrap();
        assert!(p.get("pl/mm2s.cpp").is_none());
        assert!(p.get("pl/s2mm.cpp").is_none());
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = Spec::axpydot_dataflow(65536, 2.0);
        let a = generate(&spec).unwrap();
        let b = generate(&spec).unwrap();
        assert_eq!(a.files, b.files);
    }

    #[test]
    fn invalid_spec_rejected() {
        let spec = Spec { routines: vec![], ..Default::default() };
        assert!(generate(&spec).is_err());
    }

    #[test]
    fn write_to_roundtrip(){
        let spec = Spec::single(RoutineKind::Dot, "vdot", 1024, DataSource::Pl);
        let p = generate(&spec).unwrap();
        let dir = std::env::temp_dir().join(format!("aieblas_codegen_test_{}", std::process::id()));
        p.write_to(&dir).unwrap();
        let on_disk = std::fs::read_to_string(dir.join("aie/kernels/vdot.cc")).unwrap();
        assert_eq!(on_disk, *p.get("aie/kernels/vdot.cc").unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
