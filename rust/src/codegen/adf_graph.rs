//! ADF dataflow-graph generation (`graph.h` / `graph.cpp`, Fig. 1 ③).
//!
//! Emits the `adf::graph` subclass wiring the generated kernels: window
//! connections between composed kernels (on-chip dataflow), PLIO
//! connections to the mm2s/s2mm movers for off-chip ports, and
//! `adf::location` constraints for kernels the spec pins (paper §III's
//! placement hints).

use crate::graph::build::BuildOutput;
use crate::graph::{EdgeKind, NodeKind};
use crate::spec::Spec;
use crate::Result;

/// `aie/graph.h` — the design's ADF graph class.
pub fn graph_header(spec: &Spec, built: &BuildOutput) -> Result<String> {
    let g = &built.graph;
    let mut kernels = String::new();
    let mut includes = String::new();
    let mut creates = String::new();
    let mut constraints = String::new();
    let mut plio_decls = String::new();
    let mut connects = String::new();

    for node in &g.nodes {
        match &node.kind {
            NodeKind::AieKernel { kind, window, hint, .. } => {
                includes.push_str(&format!("#include \"kernels/{}.h\"\n", node.name));
                kernels.push_str(&format!("    adf::kernel k_{};\n", node.name));
                creates.push_str(&format!(
                    "        k_{n} = adf::kernel::create({n});\n\
                     \x20       adf::source(k_{n}) = \"kernels/{n}.cc\";\n\
                     \x20       adf::runtime<ratio>(k_{n}) = 0.9;\n",
                    n = node.name
                ));
                if let Some((col, row)) = hint {
                    constraints.push_str(&format!(
                        "        adf::location<adf::kernel>(k_{}) = adf::tile({col}, {row});\n",
                        node.name
                    ));
                }
                let _ = (kind, window);
            }
            NodeKind::Combine { parts } => {
                kernels.push_str(&format!(
                    "    adf::kernel k_{}; // {parts}-way partial-sum combiner\n",
                    node.name
                ));
                creates.push_str(&format!(
                    "        k_{n} = adf::kernel::create(combine{parts});\n\
                     \x20       adf::source(k_{n}) = \"kernels/combine.cc\";\n",
                    n = node.name,
                    parts = parts
                ));
            }
            NodeKind::PlMm2s { .. } => {
                plio_decls.push_str(&format!(
                    "    adf::input_plio p_{n};\n",
                    n = node.name
                ));
            }
            NodeKind::PlS2mm { .. } => {
                plio_decls.push_str(&format!(
                    "    adf::output_plio p_{n};\n",
                    n = node.name
                ));
            }
            _ => {}
        }
    }

    for e in &g.edges {
        let src = g.node(e.src);
        let dst = g.node(e.dst);
        let window_bytes = e.window_bytes();
        match (&src.kind, &dst.kind) {
            (NodeKind::AieKernel { .. }, NodeKind::AieKernel { .. }) => {
                // on-chip dataflow connection — the paper's composition.
                let conn = match e.kind {
                    EdgeKind::Window => format!(
                        "        adf::connect<adf::window<{window_bytes}>>(k_{}.out[{}], k_{}.in[{}]); // {} -> {}\n",
                        src.name,
                        out_index(src, &e.src_port),
                        dst.name,
                        in_index(dst, &e.dst_port),
                        e.src_port,
                        e.dst_port,
                    ),
                    EdgeKind::Stream => format!(
                        "        adf::connect<adf::stream>(k_{}.out[{}], k_{}.in[{}]);\n",
                        src.name,
                        out_index(src, &e.src_port),
                        dst.name,
                        in_index(dst, &e.dst_port),
                    ),
                };
                connects.push_str(&conn);
            }
            (NodeKind::PlMm2s { .. }, NodeKind::AieKernel { .. }) => {
                connects.push_str(&format!(
                    "        p_{s} = adf::input_plio::create(\"{s}\", adf::plio_128_bits, \"data/{s}.txt\");\n\
                     \x20       adf::connect<adf::window<{window_bytes}>>(p_{s}.out[0], k_{d}.in[{i}]);\n",
                    s = src.name,
                    d = dst.name,
                    i = in_index(dst, &e.dst_port),
                ));
            }
            (NodeKind::AieKernel { .. }, NodeKind::PlS2mm { .. }) => {
                connects.push_str(&format!(
                    "        p_{d} = adf::output_plio::create(\"{d}\", adf::plio_128_bits, \"data/{d}.txt\");\n\
                     \x20       adf::connect<adf::window<{window_bytes}>>(k_{s}.out[{o}], p_{d}.in[0]);\n",
                    s = src.name,
                    d = dst.name,
                    o = out_index(src, &e.src_port),
                ));
            }
            // on-chip generators become kernels producing synthetic data in
            // the real AIEBLAS no-PL builds; model them as comments so the
            // generated graph stays compilable.
            _ => {
                connects.push_str(&format!(
                    "        // on-chip {}: {} -> {} ({} B windows)\n",
                    match src.kind {
                        NodeKind::OnChipSource => "generator",
                        _ => "sink",
                    },
                    src.name,
                    dst.name,
                    window_bytes,
                ));
            }
        }
    }

    Ok(format!(
        "// Generated by AIEBLAS — do not edit.\n\
         // Design: {} routine(s), data_source = {}\n\
         #pragma once\n\
         #include <adf.h>\n\
         {includes}\n\
         class aieblas_graph : public adf::graph {{\n\
         public:\n\
         {kernels}{plio_decls}\n\
         \x20   aieblas_graph() {{\n\
         {creates}{constraints}{connects}\
         \x20   }}\n\
         }};\n",
        spec.routines.len(),
        spec.data_source.name(),
    ))
}

/// `aie/graph.cpp` — instantiation + main for aiesimulator.
pub fn graph_source(spec: &Spec) -> String {
    format!(
        "// Generated by AIEBLAS — do not edit.\n\
         #include \"graph.h\"\n\n\
         aieblas_graph g;\n\n\
         #if defined(__AIESIM__) || defined(__X86SIM__)\n\
         int main() {{\n\
         \x20   g.init();\n\
         \x20   g.run({iterations});\n\
         \x20   g.end();\n\
         \x20   return 0;\n\
         }}\n\
         #endif\n",
        iterations = spec
            .routines
            .iter()
            .map(|r| r.size / r.effective_window().max(1))
            .max()
            .unwrap_or(1),
    )
}

fn in_index(node: &crate::graph::Node, port: &str) -> usize {
    if let NodeKind::AieKernel { kind, .. } = &node.kind {
        kind.inputs().iter().position(|p| p.name == port).unwrap_or(0)
    } else {
        0
    }
}

fn out_index(node: &crate::graph::Node, port: &str) -> usize {
    if let NodeKind::AieKernel { kind, .. } = &node.kind {
        kind.outputs().iter().position(|p| p.name == port).unwrap_or(0)
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::graph::build::build_graph;
    use crate::spec::{DataSource, Spec};

    fn header_for(spec: &Spec) -> String {
        let built = build_graph(spec).unwrap();
        graph_header(spec, &built).unwrap()
    }

    #[test]
    fn axpy_graph_declares_kernel_and_plios() {
        let spec = Spec::single(RoutineKind::Axpy, "vadd", 4096, DataSource::Pl);
        let h = header_for(&spec);
        assert!(h.contains("adf::kernel k_vadd;"));
        assert!(h.contains("adf::kernel::create(vadd)"));
        assert!(h.contains("input_plio p_vadd_x_mm2s"));
        assert!(h.contains("output_plio p_vadd_z_s2mm"));
        assert!(h.contains("class aieblas_graph : public adf::graph"));
    }

    #[test]
    fn dataflow_connection_is_window_connect() {
        let spec = Spec::axpydot_dataflow(65536, 2.0);
        let h = header_for(&spec);
        assert!(
            h.contains("adf::connect<adf::window<4096>>(k_axpy_stage.out[0], k_dot_stage.in[0])"),
            "{h}"
        );
    }

    #[test]
    fn placement_hint_becomes_location_constraint() {
        let mut spec = Spec::single(RoutineKind::Dot, "vdot", 4096, DataSource::Pl);
        spec.routines[0].placement = Some(crate::spec::Placement { col: 12, row: 4 });
        let h = header_for(&spec);
        assert!(h.contains("adf::location<adf::kernel>(k_vdot) = adf::tile(12, 4);"));
    }

    #[test]
    fn graph_source_runs_expected_iterations() {
        let spec = Spec::single(RoutineKind::Axpy, "vadd", 8192, DataSource::Pl);
        let src = graph_source(&spec);
        let w = spec.routines[0].effective_window();
        assert!(src.contains(&format!("g.run({})", 8192 / w)));
    }

    #[test]
    fn onchip_variant_has_generator_comments_not_plio() {
        let spec = Spec::single(RoutineKind::Axpy, "vadd", 4096, DataSource::OnChip);
        let h = header_for(&spec);
        assert!(!h.contains("input_plio"));
        assert!(h.contains("// on-chip generator"));
    }
}
