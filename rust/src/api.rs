//! The stable v1 wire API (DESIGN.md §13).
//!
//! Every JSON document that crosses a process boundary — HTTP request and
//! response bodies (`crate::http`), `serve-bench --metrics-json` output —
//! is shaped here, in one place, so the network edge and the tooling
//! cannot drift apart. Three rules govern the format:
//!
//! 1. **Versioned envelopes.** Every response object carries `"v": 1`
//!    ([`API_VERSION`]); requests may carry it and are rejected when it
//!    names a version this server does not speak. The version only bumps
//!    on an incompatible change, mirroring the plan store's
//!    `FORMAT_VERSION` policy (DESIGN.md §10).
//! 2. **Structured errors.** Failures are never bare strings on the wire:
//!    they are an [`ApiError`] `{code, message, retryable}` with a stable
//!    machine-readable [`ErrorCode`] mapped to a fixed HTTP status —
//!    admission sheds are 429/503, deadline failures 504, caller mistakes
//!    400-class, everything else 500-class.
//! 3. **Strict requests.** Unknown request fields are rejected (like
//!    `Spec::from_json`), so a client typo cannot silently change
//!    behavior.

use std::time::Duration;

use crate::runtime::ExecOutcome;
use crate::serve::{Priority, RequestOpts, ServeReport, ShedReason};
use crate::spec::Spec;
use crate::util::json::{obj, Json};
use crate::Error;

/// Wire-format version. Bumps only on incompatible changes to the request
/// or response shapes; additive fields do not bump it (clients must
/// ignore fields they do not know).
pub const API_VERSION: u64 = 1;

/// Value of the `retry-after` header sent with every 429/503 response.
/// One second: long enough to let a shed clear, short enough that a
/// well-behaved client's backoff dominates (DESIGN.md §14).
pub const RETRY_AFTER_SECS: u64 = 1;

/// Stable machine-readable error codes, each pinned to one HTTP status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed JSON, invalid spec, unknown request field.
    BadRequest,
    /// No such route.
    NotFound,
    /// Route exists, method does not.
    MethodNotAllowed,
    /// Request body over the configured limit.
    PayloadTooLarge,
    /// Shed at admission: bounded queue at capacity.
    ShedQueueFull,
    /// Shed at admission: non-High traffic above the watermark.
    ShedWatermark,
    /// Shed at admission: per-tenant in-flight quota exhausted.
    ShedTenantQuota,
    /// Shed at admission (or purged mid-flight): the server is draining.
    ShedDraining,
    /// The request's deadline had already passed at submit time.
    DeadlineExpired,
    /// The deadline passed while the request was queued; it was dropped
    /// before a backend run.
    DeadlineMissed,
    /// The server-side wait bound elapsed before the backend answered.
    Timeout,
    /// Proxying to the owning shard failed.
    Upstream,
    /// Transport to the owning shard: connection refused.
    UpstreamConnect,
    /// Transport to the owning shard: connect or I/O timed out.
    UpstreamTimeout,
    /// Transport to the owning shard: connection reset mid-exchange.
    UpstreamReset,
    /// Transport to the owning shard: response frame was truncated.
    UpstreamTruncated,
    /// Anything else: backend failure, panic, lost response channel.
    Internal,
}

impl ErrorCode {
    pub const ALL: [ErrorCode; 17] = [
        ErrorCode::BadRequest,
        ErrorCode::NotFound,
        ErrorCode::MethodNotAllowed,
        ErrorCode::PayloadTooLarge,
        ErrorCode::ShedQueueFull,
        ErrorCode::ShedWatermark,
        ErrorCode::ShedTenantQuota,
        ErrorCode::ShedDraining,
        ErrorCode::DeadlineExpired,
        ErrorCode::DeadlineMissed,
        ErrorCode::Timeout,
        ErrorCode::Upstream,
        ErrorCode::UpstreamConnect,
        ErrorCode::UpstreamTimeout,
        ErrorCode::UpstreamReset,
        ErrorCode::UpstreamTruncated,
        ErrorCode::Internal,
    ];

    /// The stable wire spelling.
    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::NotFound => "not_found",
            ErrorCode::MethodNotAllowed => "method_not_allowed",
            ErrorCode::PayloadTooLarge => "payload_too_large",
            ErrorCode::ShedQueueFull => "shed_queue_full",
            ErrorCode::ShedWatermark => "shed_watermark",
            ErrorCode::ShedTenantQuota => "shed_tenant_quota",
            ErrorCode::ShedDraining => "shed_draining",
            ErrorCode::DeadlineExpired => "deadline_expired",
            ErrorCode::DeadlineMissed => "deadline_missed",
            ErrorCode::Timeout => "timeout",
            ErrorCode::Upstream => "upstream",
            ErrorCode::UpstreamConnect => "upstream_connect",
            ErrorCode::UpstreamTimeout => "upstream_timeout",
            ErrorCode::UpstreamReset => "upstream_reset",
            ErrorCode::UpstreamTruncated => "upstream_truncated",
            ErrorCode::Internal => "internal",
        }
    }

    /// The HTTP status this code always maps to.
    pub fn http_status(self) -> u16 {
        match self {
            ErrorCode::BadRequest => 400,
            ErrorCode::NotFound => 404,
            ErrorCode::MethodNotAllowed => 405,
            ErrorCode::PayloadTooLarge => 413,
            ErrorCode::ShedQueueFull
            | ErrorCode::ShedWatermark
            | ErrorCode::ShedTenantQuota => 429,
            ErrorCode::ShedDraining => 503,
            ErrorCode::DeadlineExpired
            | ErrorCode::DeadlineMissed
            | ErrorCode::Timeout
            | ErrorCode::UpstreamTimeout => 504,
            ErrorCode::Upstream
            | ErrorCode::UpstreamConnect
            | ErrorCode::UpstreamReset
            | ErrorCode::UpstreamTruncated => 502,
            ErrorCode::Internal => 500,
        }
    }

    /// Whether retrying the identical request can reasonably succeed.
    /// Load sheds and transient upstream failures are retryable; caller
    /// mistakes and blown deadlines are not (the caller's deadline is
    /// gone either way).
    pub fn retryable(self) -> bool {
        matches!(
            self,
            ErrorCode::ShedQueueFull
                | ErrorCode::ShedWatermark
                | ErrorCode::ShedTenantQuota
                | ErrorCode::ShedDraining
                | ErrorCode::Timeout
                | ErrorCode::Upstream
                | ErrorCode::UpstreamConnect
                | ErrorCode::UpstreamTimeout
                | ErrorCode::UpstreamReset
                | ErrorCode::UpstreamTruncated
        )
    }

    /// Parse the wire spelling back (clients, tests, the smoke driver).
    pub fn parse(s: &str) -> Option<ErrorCode> {
        ErrorCode::ALL.iter().copied().find(|c| c.name() == s)
    }
}

/// A structured wire error: `{code, message, retryable}` inside a
/// versioned `{"v": 1, "error": …}` envelope.
#[derive(Debug, Clone)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
    pub retryable: bool,
}

impl ApiError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ApiError {
        ApiError { code, message: message.into(), retryable: code.retryable() }
    }

    /// The structured error for an admission shed, one code per reason.
    pub fn from_shed(reason: ShedReason) -> ApiError {
        let code = match reason {
            ShedReason::QueueFull => ErrorCode::ShedQueueFull,
            ShedReason::AboveWatermark => ErrorCode::ShedWatermark,
            ShedReason::TenantQuota => ErrorCode::ShedTenantQuota,
            ShedReason::Draining => ErrorCode::ShedDraining,
            ShedReason::DeadlineExpired => ErrorCode::DeadlineExpired,
        };
        ApiError::new(code, format!("request shed at admission: {reason}"))
    }

    /// Classify a crate error produced *after* admission (ticket wait,
    /// lowering, backend execution). Spec/JSON problems are the caller's;
    /// the serving layer's structured drop messages are recognized by the
    /// markers its tests already pin down; everything else is internal.
    pub fn from_error(e: &Error) -> ApiError {
        let msg = e.to_string();
        let code = match e {
            Error::Spec(_) | Error::Json(_) | Error::Graph(_) => ErrorCode::BadRequest,
            Error::Runtime(m) => {
                if m.contains("deadline expired before execution") {
                    ErrorCode::DeadlineMissed
                } else if m.contains("drained") || m.contains("draining") {
                    ErrorCode::ShedDraining
                } else if m.contains("timed out") {
                    ErrorCode::Timeout
                } else {
                    ErrorCode::Internal
                }
            }
            _ => ErrorCode::Internal,
        };
        ApiError::new(code, msg)
    }

    pub fn http_status(&self) -> u16 {
        self.code.http_status()
    }

    /// The versioned wire envelope: `{"v": 1, "error": {…}}`.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("v", (API_VERSION as f64).into()),
            (
                "error",
                obj(vec![
                    ("code", self.code.name().into()),
                    ("message", self.message.as_str().into()),
                    ("retryable", self.retryable.into()),
                ]),
            ),
        ])
    }

    /// Parse a wire error body back into a structured error (clients and
    /// the shard proxy, which relays upstream errors verbatim).
    pub fn from_json(json: &Json) -> Option<ApiError> {
        let err = json.get("error")?;
        Some(ApiError {
            code: ErrorCode::parse(err.get("code")?.as_str()?)?,
            message: err.get("message")?.as_str()?.to_string(),
            retryable: err.get("retryable")?.as_bool()?,
        })
    }
}

/// One `/v1/run` request: the spec to execute plus serving options. The
/// execution inputs are generated server-side from `seed` (deterministic
/// standard-normal, exactly `ExecInputs::random_for`), so request bodies
/// stay spec-sized; `include_values: false` additionally slims the
/// response to per-routine checksums.
#[derive(Debug, Clone)]
pub struct RunRequest {
    pub spec: Spec,
    pub tenant: Option<String>,
    pub priority: Priority,
    /// Relative deadline; the server converts it to an absolute deadline
    /// at admission. `Some(0)` is always already expired.
    pub deadline_ms: Option<u64>,
    /// Seed for the deterministic server-side input generation.
    pub seed: u64,
    /// When false, responses carry `checksum` instead of `values`.
    pub include_values: bool,
}

impl RunRequest {
    pub fn new(spec: Spec) -> RunRequest {
        RunRequest {
            spec,
            tenant: None,
            priority: Priority::Normal,
            deadline_ms: None,
            seed: 0,
            include_values: true,
        }
    }

    /// Parse a request body. Unknown top-level fields and unsupported
    /// versions are rejected — mistyped options must fail loudly, not
    /// silently run with defaults.
    pub fn from_json(json: &Json) -> Result<RunRequest, ApiError> {
        let bad = |m: String| ApiError::new(ErrorCode::BadRequest, m);
        let map = json
            .as_obj()
            .ok_or_else(|| bad("request body must be a JSON object".into()))?;
        for key in map.keys() {
            if !matches!(
                key.as_str(),
                "v" | "spec" | "tenant" | "priority" | "deadline_ms" | "seed" | "include_values"
            ) {
                return Err(bad(format!("unknown request field {key:?}")));
            }
        }
        if let Some(v) = json.get("v") {
            if v.as_u64() != Some(API_VERSION) {
                return Err(bad(format!(
                    "unsupported api version {} (this server speaks v{API_VERSION})",
                    v.to_compact()
                )));
            }
        }
        let spec_json = json.get("spec").ok_or_else(|| bad("missing \"spec\"".into()))?;
        let spec = Spec::from_json(spec_json).map_err(|e| bad(e.to_string()))?;
        let tenant = match json.get("tenant") {
            None => None,
            Some(t) => Some(
                t.as_str()
                    .ok_or_else(|| bad("\"tenant\" must be a string".into()))?
                    .to_string(),
            ),
        };
        let priority = match json.get("priority") {
            None => Priority::Normal,
            Some(p) => {
                let s = p.as_str().ok_or_else(|| bad("\"priority\" must be a string".into()))?;
                Priority::parse(s).ok_or_else(|| {
                    bad(format!("unknown priority {s:?} (high | normal | background)"))
                })?
            }
        };
        let deadline_ms = match json.get("deadline_ms") {
            None => None,
            Some(d) => Some(
                d.as_u64()
                    .ok_or_else(|| bad("\"deadline_ms\" must be a non-negative integer".into()))?,
            ),
        };
        let seed = match json.get("seed") {
            None => 0,
            Some(s) => s
                .as_u64()
                .ok_or_else(|| bad("\"seed\" must be a non-negative integer".into()))?,
        };
        let include_values = match json.get("include_values") {
            None => true,
            Some(b) => b
                .as_bool()
                .ok_or_else(|| bad("\"include_values\" must be a boolean".into()))?,
        };
        Ok(RunRequest { spec, tenant, priority, deadline_ms, seed, include_values })
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("v", (API_VERSION as f64).into()), ("spec", self.spec.to_json())];
        if let Some(t) = &self.tenant {
            pairs.push(("tenant", t.as_str().into()));
        }
        if self.priority != Priority::Normal {
            pairs.push(("priority", self.priority.name().into()));
        }
        if let Some(d) = self.deadline_ms {
            pairs.push(("deadline_ms", (d as f64).into()));
        }
        if self.seed != 0 {
            pairs.push(("seed", (self.seed as f64).into()));
        }
        if !self.include_values {
            pairs.push(("include_values", false.into()));
        }
        obj(pairs)
    }

    /// The serving-layer options this request asks for.
    pub fn opts(&self) -> RequestOpts {
        let mut opts = RequestOpts::default().with_priority(self.priority);
        if let Some(t) = &self.tenant {
            opts = opts.tenant(t);
        }
        if let Some(ms) = self.deadline_ms {
            opts = opts.with_deadline_in(Duration::from_millis(ms));
        }
        opts
    }
}

/// Render one `/v1/run` success body: per-routine outputs plus the plan
/// cache counters at response time and coarse timing. `cache` is the
/// *pipeline-lifetime* snapshot (same counters `/v1/statsz` reports), the
/// cross-process warm-start evidence the smoke driver asserts on.
pub fn run_response(
    req: &RunRequest,
    outcome: &ExecOutcome,
    cache: &crate::pipeline::CacheStats,
) -> Json {
    let outputs = Json::Arr(
        outcome
            .results
            .iter()
            .map(|r| {
                let mut pairs = vec![
                    ("routine", r.routine.as_ref().into()),
                    ("kind", r.kind.name().into()),
                    ("len", r.output.len().into()),
                ];
                if req.include_values {
                    pairs.push((
                        "values",
                        Json::Arr(r.output.iter().map(|&x| Json::Num(x as f64)).collect()),
                    ));
                } else {
                    let checksum: f64 = r.output.iter().map(|&x| x as f64).sum();
                    pairs.push(("checksum", checksum.into()));
                }
                obj(pairs)
            })
            .collect(),
    );
    let mut timing = vec![("wall_s", outcome.wall_s.into())];
    if let Some(sim) = &outcome.sim {
        timing.push(("sim_makespan_s", sim.makespan_s.into()));
    }
    obj(vec![
        ("v", (API_VERSION as f64).into()),
        ("backend", outcome.backend.into()),
        ("outputs", outputs),
        ("cache", cache_json(cache)),
        ("timing", obj(timing)),
    ])
}

/// The wire shape of the plan-cache counters, shared by `/v1/run`,
/// `/v1/statsz` (via [`report_json`]) and the smoke assertions.
pub fn cache_json(cache: &crate::pipeline::CacheStats) -> Json {
    obj(vec![
        ("hits", (cache.hits as f64).into()),
        ("coalesced", (cache.coalesced as f64).into()),
        ("misses", (cache.misses as f64).into()),
        ("evictions", (cache.evictions as f64).into()),
        ("entries", cache.entries.into()),
        ("disk_hits", (cache.disk_hits as f64).into()),
        ("disk_writes", (cache.disk_writes as f64).into()),
        ("rejected", (cache.rejected as f64).into()),
        ("tuned", (cache.tuned as f64).into()),
        ("tune_skipped", (cache.tune_skipped as f64).into()),
        ("tmp_swept", (cache.tmp_swept as f64).into()),
        ("store_fallbacks", (cache.store_fallbacks as f64).into()),
    ])
}

/// Wrap a [`ServeReport`] in the versioned envelope — the `/v1/statsz`
/// body, and what `serve-bench --metrics-json` writes, so offline tooling
/// parses one shape wherever the report came from.
pub fn report_json(report: &ServeReport) -> Json {
    match report.to_json() {
        Json::Obj(mut map) => {
            map.insert("v".into(), Json::Num(API_VERSION as f64));
            Json::Obj(map)
        }
        other => obj(vec![("v", (API_VERSION as f64).into()), ("report", other)]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::RoutineKind;
    use crate::spec::DataSource;

    #[test]
    fn error_codes_round_trip_with_fixed_statuses() {
        for code in ErrorCode::ALL {
            assert_eq!(ErrorCode::parse(code.name()), Some(code));
            assert!((400..=599).contains(&code.http_status()), "{code:?}");
        }
        assert_eq!(ErrorCode::parse("nope"), None);
        // the statuses the ISSUE pins down: shed → 429, deadline → 504,
        // caller mistakes → 400.
        assert_eq!(ErrorCode::ShedQueueFull.http_status(), 429);
        assert_eq!(ErrorCode::ShedTenantQuota.http_status(), 429);
        assert_eq!(ErrorCode::DeadlineExpired.http_status(), 504);
        assert_eq!(ErrorCode::DeadlineMissed.http_status(), 504);
        assert_eq!(ErrorCode::BadRequest.http_status(), 400);
    }

    #[test]
    fn every_shed_reason_maps_to_a_distinct_code() {
        let codes: Vec<ErrorCode> =
            ShedReason::ALL.iter().map(|&r| ApiError::from_shed(r).code).collect();
        for (i, a) in codes.iter().enumerate() {
            for b in &codes[i + 1..] {
                assert_ne!(a, b, "shed reasons must not share error codes");
            }
        }
        assert!(ApiError::from_shed(ShedReason::QueueFull).retryable);
        assert!(!ApiError::from_shed(ShedReason::DeadlineExpired).retryable);
    }

    #[test]
    fn api_error_json_round_trips() {
        let e = ApiError::new(ErrorCode::ShedDraining, "server draining");
        let parsed = ApiError::from_json(&Json::parse(&e.to_json().to_compact()).unwrap()).unwrap();
        assert_eq!(parsed.code, ErrorCode::ShedDraining);
        assert_eq!(parsed.message, "server draining");
        assert!(parsed.retryable);
        assert_eq!(e.http_status(), 503);
    }

    #[test]
    fn from_error_classifies_serving_failures() {
        let cases = [
            (Error::Spec("bad".into()), ErrorCode::BadRequest),
            (
                Error::Runtime("deadline expired before execution; request dropped".into()),
                ErrorCode::DeadlineMissed,
            ),
            (Error::Runtime("server drained before request ran".into()), ErrorCode::ShedDraining),
            (Error::Runtime("timed out after 1s waiting".into()), ErrorCode::Timeout),
            (Error::Runtime("backend panicked while executing batch".into()), ErrorCode::Internal),
        ];
        for (err, want) in cases {
            assert_eq!(ApiError::from_error(&err).code, want, "{err}");
        }
    }

    #[test]
    fn run_request_round_trips_and_rejects_junk() {
        let spec = Spec::single(RoutineKind::Axpy, "a", 256, DataSource::Pl);
        let req = RunRequest {
            tenant: Some("acme".into()),
            priority: Priority::High,
            deadline_ms: Some(250),
            seed: 7,
            include_values: false,
            ..RunRequest::new(spec)
        };
        let parsed =
            RunRequest::from_json(&Json::parse(&req.to_json().to_compact()).unwrap()).unwrap();
        assert_eq!(parsed.tenant.as_deref(), Some("acme"));
        assert_eq!(parsed.priority, Priority::High);
        assert_eq!(parsed.deadline_ms, Some(250));
        assert_eq!(parsed.seed, 7);
        assert!(!parsed.include_values);
        assert_eq!(parsed.spec.cache_key(), req.spec.cache_key());

        // unknown fields, bad version, missing spec, bad priority: all 400.
        for body in [
            r#"{"spec": {"routines": []}, "bogus": 1}"#,
            r#"{"v": 2, "spec": {"routines": []}}"#,
            r#"{"tenant": "t"}"#,
            r#"{"spec": {"routines": [{"routine": "axpy", "name": "a", "size": 64}]}, "priority": "urgent"}"#,
            r#"[1, 2]"#,
        ] {
            let err = RunRequest::from_json(&Json::parse(body).unwrap()).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "{body}");
        }
    }

    #[test]
    fn opts_carry_tenant_priority_deadline() {
        let spec = Spec::single(RoutineKind::Dot, "d", 64, DataSource::Pl);
        let req = RunRequest {
            tenant: Some("t".into()),
            priority: Priority::Background,
            deadline_ms: Some(1_000),
            ..RunRequest::new(spec)
        };
        let opts = req.opts();
        assert_eq!(opts.tenant.as_deref(), Some("t"));
        assert_eq!(opts.priority, Priority::Background);
        assert!(opts.deadline.is_some());
        // deadline_ms: 0 must produce an already-expired deadline.
        let req0 = RunRequest { deadline_ms: Some(0), ..req };
        assert!(req0.opts().deadline.unwrap() <= std::time::Instant::now());
    }
}
